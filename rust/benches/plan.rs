//! Planner scale-out trajectory benchmark → `BENCH_plan.json`.
//!
//! Per tier (small 64x8, medium 256x24, full 2048x192) this measures:
//! plan and replan wall time through the trained RF estimator, simulated
//! serving throughput of the resulting placement — on the lockstep twin
//! and through the event-driven serving core (DESIGN.md §12) — and the
//! serial vs parallel DT probe fan-out.  The small tier also times MinCost
//! planning over a two-class fleet (`plan_fleet_min_cost_wall_s`).  The
//! full tier is ML-plan-only — probing the twin for 192 GPUs is exactly
//! the cost the data-driven planner exists to avoid.
//!
//! Modes:
//!
//! ```sh
//! cargo bench --bench plan                  # refresh BENCH_plan.json (all tiers)
//! cargo bench --bench plan -- --tier small --tier medium --check
//! ```
//!
//! The check gate always enforces the live medium-tier probe speedup
//! (>=2x when >=4 cores are available); the >25% wall-time regression
//! gate arms only once the checked-in baseline carries measured numbers
//! (`"measured": true`).  The hand-authored bootstrap baseline
//! (`"measured": false`) pins the schema without pinning a machine, and
//! wall-time comparisons are normalized by the ratio of `ref_twin_sim_s`
//! (one fixed twin simulation timed on both machines).

use std::collections::BTreeMap;

use adapter_serving::cluster::epochs::{serve_horizon, HorizonBackend, ReplanPolicy};
use adapter_serving::cluster::{self, Core, RunOptions};
use adapter_serving::config::{EngineConfig, FleetSpec, GpuTypeSpec};
use adapter_serving::dt::{self, Calibration, LengthVariant};
use adapter_serving::ml::{self, dataset::GridSpec, MlModels};
use adapter_serving::placement::{
    fleet, plan, replan, replan_with_ledger, CachedEstimator, MinCost, MinGpus, MlEstimator,
    PerfEstimator, ProbeQuery, ReplanLedger, TwinEstimator,
};
use adapter_serving::util::bench::bench_auto;
use adapter_serving::util::json::Json;
use adapter_serving::util::threadpool::default_workers;
use adapter_serving::workload::drift::DriftSpec;
use adapter_serving::workload::{AdapterSpec, WorkloadSpec};
use anyhow::{anyhow, bail};

/// The checked-in baseline, at the repository root next to README.md.
const BASELINE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_plan.json");

/// Allowed wall-time growth over the baseline (the >25% regression gate).
const REGRESSION_SLACK: f64 = 1.25;

struct TierSpec {
    name: &'static str,
    adapters: usize,
    gpus: usize,
    /// Twin-backed metrics (simulated throughput + probe fan-out) are
    /// only measured below full scale.
    probe: bool,
}

const TIERS: [TierSpec; 3] = [
    TierSpec { name: "small", adapters: 64, gpus: 8, probe: true },
    TierSpec { name: "medium", adapters: 256, gpus: 24, probe: true },
    TierSpec { name: "full", adapters: 2048, gpus: 192, probe: false },
];

fn main() -> anyhow::Result<()> {
    let (tier_names, check) = parse_args()?;
    let mode = if check { "check" } else { "refresh" };
    println!("# plan-trajectory benchmark ({mode} mode)");
    let calib = Calibration::default();
    let base = EngineConfig::default();
    println!("training the RF planning estimator (shared across tiers) ...");
    let est = trained_estimator(&calib, &base);
    let ref_live = ref_twin_sim(&calib);
    let mut live: Vec<(String, Json)> = Vec::new();
    for name in &tier_names {
        let t = TIERS.iter().find(|t| t.name == name.as_str()).unwrap();
        live.push((t.name.to_string(), run_tier(t, &est, &calib, &base)?));
    }
    if check {
        check_against_baseline(ref_live, &live)
    } else {
        write_refresh(ref_live, live)
    }
}

fn parse_args() -> anyhow::Result<(Vec<String>, bool)> {
    let mut tiers = Vec::new();
    let mut check = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tier" => {
                let t = args.next().ok_or_else(|| anyhow!("--tier needs a value"))?;
                if !TIERS.iter().any(|s| s.name == t) {
                    bail!("unknown tier '{t}' (expected small, medium or full)");
                }
                tiers.push(t);
            }
            "--check" => check = true,
            "--bench" => {} // appended by `cargo bench`
            other => bail!("unknown argument '{other}'"),
        }
    }
    if tiers.is_empty() {
        tiers = TIERS.iter().map(|t| t.name.to_string()).collect();
    }
    Ok((tiers, check))
}

/// The same quick training grid the integration tests use: enough signal
/// for clear-cut feasibility calls at a bench-friendly training cost.
fn trained_estimator(calib: &Calibration, base: &EngineConfig) -> MlEstimator {
    let grid = GridSpec {
        sizes: vec![8, 16, 32],
        rates: vec![0.8, 0.2, 0.05, 0.0125],
        adapter_counts: vec![8, 16, 32, 64, 96, 128],
        a_max_values: vec![8, 16, 32, 64, 96, 128],
        horizon_s: 10.0,
        max_scenarios: 400,
        seed: 99,
    };
    let samples = ml::dataset::generate(calib, base, &grid, 4);
    let rf = ml::ModelType::RandomForest;
    let (thr, _) = ml::train(&samples, ml::Task::Throughput, rf, true, 3);
    let (st, _) = ml::train(&samples, ml::Task::Starvation, rf, true, 3);
    MlEstimator::new(MlModels { throughput: thr, starvation: st, scaler: None })
}

/// One fixed twin simulation used as the cross-machine speed reference.
fn ref_twin_sim(calib: &Calibration) -> f64 {
    let cfg = EngineConfig { a_max: 32, s_max_rank: 16, ..Default::default() };
    let spec = WorkloadSpec::sharegpt_like(
        WorkloadSpec::heterogeneous(32, &[8, 16], &[0.1, 0.05], 5),
        10.0,
        4,
    );
    let r = bench_auto("ref_twin_sim_32x10s", 1.0, || {
        std::hint::black_box(dt::run_twin(&cfg, calib, &spec, LengthVariant::Mean));
    });
    r.p50_s
}

/// A drifted copy of the workload: every 7th adapter's rate grows 1.5x,
/// enough churn that the repair pass does real work on every tier.
fn drifted(adapters: &[AdapterSpec]) -> Vec<AdapterSpec> {
    let mut out = adapters.to_vec();
    for a in out.iter_mut().filter(|a| a.id % 7 == 0) {
        a.rate *= 1.5;
    }
    out
}

fn run_tier(
    t: &TierSpec,
    est: &MlEstimator,
    calib: &Calibration,
    base: &EngineConfig,
) -> anyhow::Result<Json> {
    println!("## tier {} ({} adapters / {} gpus)", t.name, t.adapters, t.gpus);
    let adapters = WorkloadSpec::heterogeneous(t.adapters, &[8, 16], &[0.05, 0.025], 7);
    let prev = plan(&adapters, t.gpus, est, &MinGpus)
        .map_err(|e| anyhow!("tier {}: ML planning failed: {e}", t.name))?;
    let plan_wall = bench_auto(&format!("plan_ml_{}", t.name), 1.0, || {
        let _ = std::hint::black_box(plan(&adapters, t.gpus, est, &MinGpus));
    });

    let moved = drifted(&adapters);
    let params = replan::ReplanParams::default();
    let replan_wall = bench_auto(&format!("replan_ml_{}", t.name), 1.0, || {
        // A fresh ledger per iteration keeps the repair work constant.
        let mut ledger = ReplanLedger::new();
        let out = replan_with_ledger(
            Some(&prev),
            &moved,
            t.gpus,
            est,
            &params,
            &MinGpus,
            Some(&mut ledger),
        );
        let _ = std::hint::black_box(out);
    });

    let mut fields = vec![
        ("adapters", Json::Num(t.adapters as f64)),
        ("gpus", Json::Num(t.gpus as f64)),
        ("plan_ml_wall_s", Json::Num(plan_wall.p50_s)),
        ("replan_ml_wall_s", Json::Num(replan_wall.p50_s)),
    ];
    if t.name == "small" {
        // Heterogeneous-fleet cost planning at small scale: a catalog
        // a10g pool plus a half-size a100 pool, with MinCost probing
        // the open candidates per fresh GPU.
        let a10g = GpuTypeSpec::catalog("a10g").expect("a10g in catalog");
        let a100 = GpuTypeSpec::catalog("a100").expect("a100 in catalog");
        let fleet_spec = FleetSpec::new(vec![(a10g, t.gpus), (a100, t.gpus / 2)]);
        let ests: [&dyn PerfEstimator; 2] = [est, est];
        let fleet_wall = bench_auto(&format!("plan_fleet_min_cost_{}", t.name), 1.0, || {
            let _ = std::hint::black_box(fleet::place(&adapters, &fleet_spec, &ests, &MinCost));
        });
        fields.push(("plan_fleet_min_cost_wall_s", Json::Num(fleet_wall.p50_s)));
    }
    if t.probe {
        let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 10.0, 8);
        let opts = RunOptions::new();
        let rep =
            cluster::serve_on_twin(calib, base, &prev, &spec, LengthVariant::Original, opts);

        // Probe the planned groups through the twin, serially and fanned
        // out; a fresh memo per iteration keeps every probe a miss.
        let mut per_gpu: Vec<Vec<AdapterSpec>> = vec![Vec::new(); t.gpus];
        for a in &adapters {
            per_gpu[prev.assignment[&a.id]].push(a.clone());
        }
        let queries: Vec<ProbeQuery<'_>> = (0..t.gpus)
            .filter(|&g| !per_gpu[g].is_empty())
            .map(|g| ProbeQuery { adapters: &per_gpu[g], a_max: prev.a_max[g] })
            .collect();
        let twin = || TwinEstimator::new(calib.clone(), base.clone()).horizon(5.0);
        let serial = bench_auto(&format!("probe_{}_serial", t.name), 1.0, || {
            let cached = CachedEstimator::wrap(twin()).probe_workers(1);
            std::hint::black_box(cached.estimate_batch(&queries));
        });
        let pw = default_workers().min(8);
        let parallel = bench_auto(&format!("probe_{}_parallel_w{pw}", t.name), 1.0, || {
            let cached = CachedEstimator::wrap(twin()).probe_workers(pw);
            std::hint::black_box(cached.estimate_batch(&queries));
        });
        let speedup = serial.p50_s / parallel.p50_s.max(1e-12);
        println!("bench probe_{} speedup: {speedup:.2}x over serial ({pw} workers)", t.name);
        // The same placement problem served through the event-driven core
        // (DESIGN.md §12): one steady epoch under the static policy, so
        // the row isolates the calendar-queue loop from replanning cost.
        let drift = DriftSpec::steady(adapters.clone(), 1, 10.0, 8);
        let backend = HorizonBackend::Twin { calib, variant: LengthVariant::Original };
        let event = serve_horizon(
            backend,
            base,
            &drift,
            t.gpus,
            est,
            &MinGpus,
            &ReplanPolicy::Static,
            Core::EventDriven,
            RunOptions::new(),
        )?;
        let event_wall = bench_auto(&format!("serve_event_{}", t.name), 1.0, || {
            let r = serve_horizon(
                backend,
                base,
                &drift,
                t.gpus,
                est,
                &MinGpus,
                &ReplanPolicy::Static,
                Core::EventDriven,
                RunOptions::new(),
            );
            let _ = std::hint::black_box(r);
        });
        fields.push(("sim_throughput_tok_s", Json::Num(rep.total_throughput_tok_s)));
        fields.push(("sim_event_throughput_tok_s", Json::Num(event.mean_throughput_tok_s)));
        fields.push(("serve_event_wall_s", Json::Num(event_wall.p50_s)));
        fields.push(("probe_serial_wall_s", Json::Num(serial.p50_s)));
        fields.push(("probe_parallel_wall_s", Json::Num(parallel.p50_s)));
        fields.push(("probe_speedup_x", Json::Num(speedup)));
    }
    Ok(Json::obj(fields))
}

fn check_against_baseline(ref_live: f64, live: &[(String, Json)]) -> anyhow::Result<()> {
    let baseline = Json::read_file(std::path::Path::new(BASELINE))?;
    let mut failures: Vec<String> = Vec::new();

    // Live gate, independent of the baseline: the parallel probe fan-out
    // must win >=2x at medium scale when the machine has >=4 cores.
    if let Some((_, tier)) = live.iter().find(|(n, _)| n == "medium") {
        let speedup = tier.get("probe_speedup_x").and_then(Json::as_f64).unwrap_or(0.0);
        let cores = default_workers();
        if cores >= 4 && speedup < 2.0 {
            failures.push(format!("medium probe speedup {speedup:.2}x < 2.0x on {cores} cores"));
        } else {
            println!("check: medium probe speedup {speedup:.2}x ({cores} cores)");
        }
    }

    let measured = baseline.get("measured").and_then(Json::as_bool).unwrap_or(false);
    if !measured {
        println!("check: baseline is the unmeasured bootstrap; wall-time gate skipped");
    } else {
        let ref_base = baseline.get("ref_twin_sim_s").and_then(Json::as_f64).unwrap_or(0.0);
        let machine = if ref_base > 0.0 { ref_live / ref_base } else { 1.0 };
        println!("check: machine factor {machine:.2}x vs the baseline machine");
        for (name, tier) in live {
            let Some(b) = baseline.get("tiers").and_then(|ts| ts.get(name)) else {
                println!("check: tier {name} absent from the baseline; skipped");
                continue;
            };
            for metric in ["plan_ml_wall_s", "replan_ml_wall_s", "plan_fleet_min_cost_wall_s"] {
                let lv = tier.get(metric).and_then(Json::as_f64);
                let bv = b.get(metric).and_then(Json::as_f64);
                let (Some(lv), Some(bv)) = (lv, bv) else { continue };
                let allowed = bv * REGRESSION_SLACK * machine;
                if lv > allowed {
                    failures.push(format!(
                        "{name}.{metric}: {lv:.3}s > allowed {allowed:.3}s (baseline {bv:.3}s)"
                    ));
                } else {
                    println!("check: {name}.{metric} {lv:.3}s <= {allowed:.3}s");
                }
            }
        }
    }

    if failures.is_empty() {
        println!("check: PASS");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("check: FAIL {f}");
        }
        bail!("plan bench regression gate failed ({} checks)", failures.len())
    }
}

fn write_refresh(ref_live: f64, live: Vec<(String, Json)>) -> anyhow::Result<()> {
    let path = std::path::Path::new(BASELINE);
    let old = Json::read_file(path).ok();
    // Partial refreshes keep the other tiers' previous numbers; the file
    // is only marked measured once every tier ran live (or already was).
    let mut tiers: BTreeMap<String, Json> = old
        .as_ref()
        .and_then(|j| j.get("tiers").and_then(Json::as_obj).cloned())
        .unwrap_or_default();
    let prev_measured =
        old.as_ref().and_then(|j| j.get("measured").and_then(Json::as_bool)).unwrap_or(false);
    let all_live = TIERS.iter().all(|t| live.iter().any(|(n, _)| n == t.name));
    for (name, tier) in live {
        tiers.insert(name, tier);
    }
    let measured = prev_measured || all_live;
    let doc = Json::obj(vec![
        ("measured", Json::Bool(measured)),
        ("ref_twin_sim_s", Json::Num(ref_live)),
        ("schema", Json::Num(1.0)),
        ("tiers", Json::Obj(tiers)),
    ]);
    doc.write_file(path)?;
    println!("wrote {} (measured: {measured})", path.display());
    Ok(())
}
