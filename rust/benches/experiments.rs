//! End-to-end experiment benches: one per paper table/figure, at quick
//! scale.  `cargo bench` regenerates every evaluation artifact into
//! `results/` and times each (captured in bench_output.txt).

// Bench binaries time things by definition; the clippy wall-clock
// disallow (clippy.toml) is lifted file-wide here.
#![allow(clippy::disallowed_methods)]

use adapter_serving::experiments::{self, ExpContext, Scale};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    println!("# paper experiments (quick scale) — one bench per table/figure");
    let ctx = ExpContext::new(Scale::Quick);
    // Order matters: table1 populates the validation cache that tables 3/4
    // reuse; common caches (calibration/dataset/models) build on first use.
    let order = [
        "fig1", "fig4", "fig5", "fig6", "fig7", "table1", "fig8", "fig9", "table3", "table4",
        "fig10", "fig11", "table5", "fig12", "figa13",
    ];
    let mut rows = vec![];
    for id in order {
        let t0 = Instant::now();
        experiments::run(id, &ctx)?;
        let dt = t0.elapsed().as_secs_f64();
        println!("bench experiment/{id:<8} completed in {dt:>8.2}s");
        rows.push((id, dt));
    }
    println!("\n# summary");
    for (id, dt) in rows {
        println!("bench experiment/{id:<8} {dt:>8.2}s");
    }
    Ok(())
}
