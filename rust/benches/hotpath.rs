//! Hot-path micro-benchmarks (criterion-free harness, see util::bench):
//! backend decode/prefill per bucket, KV window gather, bank write, twin
//! iteration, parallel vs serial cluster validation and probe fan-out,
//! ML inference.
//! `cargo bench` → bench_output.txt.

use adapter_serving::cluster;
use adapter_serving::config::EngineConfig;
use adapter_serving::dt::{self, Calibration};
use adapter_serving::engine::kv::RequestKv;
use adapter_serving::ml;
use adapter_serving::placement::{
    CachedEstimator, PerfEstimator, Placement, ProbeQuery, TwinEstimator,
};
use adapter_serving::runtime::{load_backend, Backend, Manifest};
use adapter_serving::util::bench::bench_auto;
use adapter_serving::util::rng::Rng;
use adapter_serving::util::threadpool::default_workers;
use adapter_serving::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    println!("# hotpath micro-benchmarks");
    let mut rt: Box<dyn Backend> = load_backend(&Manifest::default_dir(), "pico-llama")?;
    let meta = rt.meta().clone();
    let (l, d, w) = (meta.n_layers, meta.d_model, meta.window);

    // --- backend decode per bucket --------------------------------------
    for bucket in [1usize, 8, 64] {
        let tokens = vec![1i32; bucket];
        let k_win = vec![0.1f32; l * bucket * w * d];
        let v_win = vec![0.1f32; l * bucket * w * d];
        let ctx = vec![64i32; bucket];
        let slot = vec![0i32; bucket];
        bench_auto(&format!("decode_b{bucket}"), 1.0, || {
            rt.decode(bucket, &tokens, &k_win, &v_win, &ctx, &slot).unwrap();
        });
    }

    // --- prefill per bucket ---------------------------------------------
    for bucket in [32usize, 256] {
        let tokens = vec![1i32; bucket];
        bench_auto(&format!("prefill_s{bucket}"), 1.0, || {
            rt.prefill(bucket, &tokens, bucket - 1, 0).unwrap();
        });
    }

    // --- KV window gather (pure rust hot loop) ---------------------------
    let mut kv = RequestKv::default();
    let row_k = vec![0.5f32; l * d];
    let row_v = vec![0.25f32; l * d];
    for _ in 0..256 {
        kv.append(l, d, &row_k, &row_v);
    }
    let mut dst_k = vec![0f32; (w - 1) * d];
    let mut dst_v = vec![0f32; (w - 1) * d];
    bench_auto("kv_gather_window_127", 0.5, || {
        for layer in 0..l {
            kv.gather_window(layer, l, d, w - 1, &mut dst_k, &mut dst_v);
        }
    });

    // --- adapter bank slot write + upload --------------------------------
    let a_len = d * meta.max_rank;
    let b_len = meta.max_rank * d;
    let a_q = vec![0.01f32; l * a_len];
    let b_q = vec![0.01f32; l * b_len];
    bench_auto("bank_write_and_upload", 1.0, || {
        rt.write_bank_slot(3, &a_q, &b_q, &a_q, &b_q).unwrap();
        rt.upload_bank().unwrap();
    });

    // --- Digital Twin full run -------------------------------------------
    let calib = Calibration::default();
    let cfg = EngineConfig { a_max: 32, s_max_rank: 16, ..Default::default() };
    let spec = WorkloadSpec::sharegpt_like(
        WorkloadSpec::heterogeneous(64, &[8, 16], &[0.1, 0.05], 3),
        30.0,
        4,
    );
    bench_auto("twin_run_64_adapters_30s", 2.0, || {
        let _ = dt::run_twin(&cfg, &calib, &spec, dt::LengthVariant::Mean);
    });

    // --- Cluster validation: serial vs parallel twin sweep ----------------
    // Acceptance gate for the parallel path: identical ClusterReport
    // aggregates (asserted in cluster::tests) at a >=2x wall-clock win on
    // a 4-GPU placement when >=4 cores are available (capped by cores).
    let cl_adapters = WorkloadSpec::heterogeneous(96, &[8, 16], &[0.2, 0.1], 7);
    let cl_spec = WorkloadSpec::sharegpt_like(cl_adapters.clone(), 30.0, 8);
    let mut placement = Placement { assignment: Default::default(), a_max: vec![24, 24, 24, 24] };
    for a in &cl_adapters {
        placement.assignment.insert(a.id, a.id % 4);
    }
    let base = EngineConfig::default();
    const VARIANT: dt::LengthVariant = dt::LengthVariant::Original;
    let serial = bench_auto("cluster_twin_4gpu_serial", 2.0, || {
        let opts = cluster::RunOptions::new().workers(1);
        let _ = cluster::serve_on_twin(&calib, &base, &placement, &cl_spec, VARIANT, opts);
    });
    let workers = default_workers().min(4);
    let parallel = bench_auto(&format!("cluster_twin_4gpu_parallel_w{workers}"), 2.0, || {
        let opts = cluster::RunOptions::new().workers(workers);
        let _ = cluster::serve_on_twin(&calib, &base, &placement, &cl_spec, VARIANT, opts);
    });
    println!(
        "bench cluster_twin_4gpu speedup: {:.2}x over serial ({} workers, {} cores)",
        serial.mean_s / parallel.mean_s.max(1e-12),
        workers,
        default_workers(),
    );

    // --- Probe fan-out: serial vs parallel estimate_batch -----------------
    // A fresh CachedEstimator per iteration keeps every probe a miss, so
    // this measures the fan-out itself, not memo hits.
    let groups: Vec<Vec<_>> = (0..8u64)
        .map(|g| WorkloadSpec::heterogeneous(12, &[8, 16], &[0.2, 0.1], 40 + g))
        .collect();
    let queries: Vec<ProbeQuery<'_>> =
        groups.iter().map(|g| ProbeQuery { adapters: g, a_max: 32 }).collect();
    let twin = || TwinEstimator::new(calib.clone(), base.clone()).horizon(5.0);
    let probe_serial = bench_auto("probe_batch_8x12_serial", 2.0, || {
        let est = CachedEstimator::wrap(twin()).probe_workers(1);
        std::hint::black_box(est.estimate_batch(&queries));
    });
    let pw = default_workers().min(8);
    let probe_parallel = bench_auto(&format!("probe_batch_8x12_parallel_w{pw}"), 2.0, || {
        let est = CachedEstimator::wrap(twin()).probe_workers(pw);
        std::hint::black_box(est.estimate_batch(&queries));
    });
    println!(
        "bench probe_batch speedup: {:.2}x over serial ({pw} workers)",
        probe_serial.mean_s / probe_parallel.mean_s.max(1e-12),
    );

    // --- ML inference -----------------------------------------------------
    let mut rng = Rng::new(1);
    let xs: Vec<Vec<f64>> = (0..512)
        .map(|_| (0..ml::N_FEATURES).map(|_| rng.f64() * 100.0).collect())
        .collect();
    let ys: Vec<f64> = xs.iter().map(|x| x[1] * 96.0).collect();
    let forest = ml::forest::Forest::fit(
        &xs,
        &ys,
        &ml::forest::ForestParams { n_estimators: 128, ..Default::default() },
    );
    let tree = ml::refine::distill(&xs, &ys, ml::tree::Criterion::Mse, 32);
    let flat = ml::refine::FlatTree::compile(&tree);
    bench_auto("rf128_predict_one", 0.5, || {
        std::hint::black_box(forest.predict_one(&xs[7]));
    });
    bench_auto("small_tree_predict_one", 0.5, || {
        std::hint::black_box(tree.predict_one(&xs[7]));
    });
    bench_auto("small_tree_flat_predict_one", 0.5, || {
        std::hint::black_box(flat.predict_one(&xs[7]));
    });
    Ok(())
}
