//! `detlint` — determinism & correctness static analysis for the
//! `adapter_serving` crate (DESIGN.md §13).
//!
//! Scans `rust/src/**`, `rust/tools/**` and `rust/benches/**` with a
//! hand-rolled token-level pass and enforces eight rules:
//!
//! * `unordered-iter` — no `HashMap`/`HashSet` iteration in
//!   determinism-critical modules;
//! * `wall-clock` — no `Instant::now`/`SystemTime` outside timing
//!   modules;
//! * `float-key` — fingerprint/memo-key code must route floats
//!   through `to_bits()`;
//! * `ambient-entropy` — no `thread::spawn` outside
//!   `util::threadpool`, no unseeded randomness outside `util::rng`;
//! * `deprecated` — no in-crate `#[deprecated]` APIs;
//! * `unit-mix` — no arithmetic/comparison/assignment across
//!   disagreeing unit suffixes (`_s`, `_ms`, `_tok_s`, `_req_s`,
//!   `_bytes`, `_usd_hr`, `_tokens`) outside the sanctioned
//!   conversion lattice;
//! * `lossy-cast` — no truncating/wrapping `as` casts in the
//!   accounting modules;
//! * `panic-path` — no `.unwrap()`/`.expect(…)`/`panic!`/
//!   `unreachable!`/non-literal indexing in the serving hot paths.
//!
//! Violations are silenced only by an inline `detlint` waiver comment
//! (the rule id in an `allow` clause, then a dash and a mandatory
//! reason) on the offending line or up to two lines above;
//! the per-rule waiver count is capped by `waiver-budget.txt`, and a
//! stale waiver (covering nothing) fails `--check` outright.
//!
//! ```text
//! cargo run -p detlint -- --check            # CI gate: non-zero exit on any finding
//! cargo run -p detlint -- --waivers          # print the waiver inventory only
//! cargo run -p detlint -- --root DIR --budget FILE
//! ```

mod config;
mod lexer;
mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A violation tagged with its file, plus the waiver that covers it
/// (if any).
struct Finding {
    rel: String,
    violation: rules::Violation,
    waived_by: Option<rules::Waiver>,
}

/// Full scan result over the tree.
#[derive(Default)]
struct Report {
    findings: Vec<Finding>,
    /// All waivers seen, as `(rel, waiver, used)`.
    waivers: Vec<(String, rules::Waiver, bool)>,
    files: usize,
}

/// Scan one root.  `display` prefixes every reported path
/// (`rust/src/`), `module_prefix` namespaces the derived module paths
/// (`""` for the main crate, `"tools"` / `"benches"` for the self-lint
/// roots).  Findings and waivers accumulate into `report`.
fn scan_tree(
    src_root: &Path,
    display: &str,
    module_prefix: &str,
    report: &mut Report,
) -> Result<(), String> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();

    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let shown = format!("{display}{rel}");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let toks = lexer::lex(&src);
        let module = config::module_path_prefixed(module_prefix, &rel);
        let violations = rules::analyze(&module, &rel, &toks);
        let waivers = rules::parse_waivers(&toks);
        let mut used = vec![false; waivers.len()];
        for v in violations {
            let hit = waivers
                .iter()
                .enumerate()
                .find(|(_, w)| rules::waiver_covers(w, v.rule, v.line));
            let waived_by = hit.map(|(i, w)| {
                used[i] = true;
                w.clone()
            });
            report.findings.push(Finding { rel: shown.clone(), violation: v, waived_by });
        }
        for (w, u) in waivers.into_iter().zip(used) {
            report.waivers.push((shown.clone(), w, u));
        }
        report.files += 1;
    }
    Ok(())
}

/// The three scan roots under the repository root: the crate sources
/// plus the self-lint roots (the lint tool itself and the bench
/// harnesses obey the same contract).
const SCAN_ROOTS: [(&str, &str, &str); 3] = [
    ("rust/src", "rust/src/", ""),
    ("rust/tools", "rust/tools/", "tools"),
    ("rust/benches", "rust/benches/", "benches"),
];

fn scan_repo(root: &Path) -> Result<Report, String> {
    let mut report = Report::default();
    for (sub, display, module_prefix) in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            scan_tree(&dir, display, module_prefix, &mut report)?;
        }
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            // Build artifacts under a nested `target/` are not source.
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Parse `waiver-budget.txt`: `<rule-id> <max-count>` per line, `#`
/// comments.  Rules absent from the file have budget 0.
fn parse_budget(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut out = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(rule), Some(count)) = (it.next(), it.next()) else {
            return Err(format!("budget line {}: expected `<rule> <count>`", ln + 1));
        };
        if !config::RULE_IDS.contains(&rule) {
            return Err(format!("budget line {}: unknown rule `{rule}`", ln + 1));
        }
        let n: usize =
            count.parse().map_err(|e| format!("budget line {}: {e}", ln + 1))?;
        out.insert(rule.to_string(), n);
    }
    Ok(out)
}

/// Everything `--check` enforces, as (ok, rendered report).
fn check(report: &Report, budget: &BTreeMap<String, usize>) -> (bool, String) {
    let mut out = String::new();
    let mut ok = true;

    let active: Vec<&Finding> =
        report.findings.iter().filter(|f| f.waived_by.is_none()).collect();
    if active.is_empty() {
        out.push_str(&format!(
            "detlint: {} files scanned, 0 unwaivered violations\n",
            report.files
        ));
    } else {
        ok = false;
        out.push_str(&format!("detlint: {} violation(s):\n", active.len()));
        for f in &active {
            out.push_str(&format!(
                "  {}:{} [{}] {}\n",
                f.rel, f.violation.line, f.violation.rule, f.violation.msg
            ));
        }
    }

    // Waiver inventory, with reasons — the audited budget.  A stale
    // waiver is an error, not a warning: it silently re-opens budget
    // headroom for a future violation nobody reviewed.
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    let mut no_reason = 0usize;
    out.push_str("waiver inventory:\n");
    for (rel, w, used) in &report.waivers {
        if !used {
            ok = false;
            out.push_str(&format!(
                "  ERROR: stale waiver {rel}:{} [{}] covers nothing — delete it\n",
                w.line, w.rule
            ));
            continue;
        }
        if w.reason.is_empty() {
            ok = false;
            no_reason += 1;
            out.push_str(&format!(
                "  ERROR: waiver without reason at {rel}:{} [{}]\n",
                w.line, w.rule
            ));
            continue;
        }
        *counts.entry(w.rule.as_str()).or_default() += 1;
        out.push_str(&format!("  {rel}:{} [{}] — {}\n", w.line, w.rule, w.reason));
    }
    if report.waivers.iter().all(|(_, _, used)| !used) {
        out.push_str("  (none)\n");
    }
    if no_reason > 0 {
        out.push_str(&format!("{no_reason} waiver(s) missing a reason\n"));
    }

    // Per-rule inventory: how many findings each rule produced, split
    // into waived vs active, against the checked-in budget — the one
    // block a CI log reader needs to audit budget drift.
    let mut waived_by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    let mut active_by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    for f in &report.findings {
        let m = if f.waived_by.is_some() { &mut waived_by_rule } else { &mut active_by_rule };
        *m.entry(f.violation.rule).or_default() += 1;
    }
    out.push_str("per-rule inventory (active / waived findings; waivers vs budget):\n");
    for rule in config::RULE_IDS {
        let act = active_by_rule.get(rule).copied().unwrap_or(0);
        let wvd = waived_by_rule.get(rule).copied().unwrap_or(0);
        let have = counts.get(rule).copied().unwrap_or(0);
        let max = budget.get(rule).copied().unwrap_or(0);
        let status = if have > max {
            "EXCEEDED"
        } else if act > 0 {
            "FAILING"
        } else {
            "ok"
        };
        out.push_str(&format!(
            "  {rule}: {act} active, {wvd} waived; waivers {have}/{max} {status}\n"
        ));
        if have > max {
            ok = false;
        }
    }
    (ok, out)
}

fn default_root() -> PathBuf {
    // tools/detlint sits at <repo>/rust/tools/detlint.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..").canonicalize().unwrap_or_else(|_| {
        PathBuf::from(".")
    })
}

fn main() -> ExitCode {
    let mut check_mode = false;
    let mut waivers_only = false;
    let mut root = default_root();
    let mut budget_path: Option<PathBuf> = None;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => check_mode = true,
            "--waivers" => waivers_only = true,
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => return usage("--root needs a directory"),
            },
            "--budget" => match args.next() {
                Some(f) => budget_path = Some(PathBuf::from(f)),
                None => return usage("--budget needs a file"),
            },
            "--help" | "-h" => {
                println!(
                    "detlint [--check] [--waivers] [--root DIR] [--budget FILE]\n\
                     determinism & correctness lint over rust/src, rust/tools and \
                     rust/benches — see DESIGN.md §13"
                );
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    if !root.join("rust/src").is_dir() {
        eprintln!("detlint: source root {} not found", root.join("rust/src").display());
        return ExitCode::from(2);
    }
    let report = match scan_repo(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };

    if waivers_only {
        for (rel, w, used) in &report.waivers {
            let mark = if *used { "" } else { " (stale)" };
            println!("{rel}:{} [{}]{} — {}", w.line, w.rule, mark, w.reason);
        }
        return ExitCode::SUCCESS;
    }

    let budget_file =
        budget_path.unwrap_or_else(|| root.join("rust/tools/detlint/waiver-budget.txt"));
    let budget = match std::fs::read_to_string(&budget_file) {
        Ok(text) => match parse_budget(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("detlint: {}: {e}", budget_file.display());
                return ExitCode::from(2);
            }
        },
        Err(e) if check_mode => {
            eprintln!("detlint: budget file {}: {e}", budget_file.display());
            return ExitCode::from(2);
        }
        Err(_) => BTreeMap::new(),
    };

    let (ok, rendered) = check(&report, &budget);
    print!("{rendered}");
    if check_mode && !ok {
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("detlint: {msg} (try --help)");
    ExitCode::from(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_parsing() {
        let b = parse_budget("# comment\nwall-clock 9\nunordered-iter 1 # inline\n").unwrap();
        assert_eq!(b.get("wall-clock"), Some(&9));
        assert_eq!(b.get("unordered-iter"), Some(&1));
        assert!(parse_budget("no-such-rule 3\n").is_err());
        assert!(parse_budget("wall-clock\n").is_err());
    }

    /// The CI gate as a tier-1 test: the real tree (all three scan
    /// roots) must scan clean — zero unwaivered violations, every
    /// waiver reasoned, no stale waivers, all within the checked-in
    /// budget.
    #[test]
    fn repo_tree_is_clean_under_budget() {
        let root = default_root();
        assert!(root.join("rust/src").is_dir(), "source root missing under {}", root.display());
        let report = scan_repo(&root).expect("scan");
        // ~60 crate files plus the self-lint roots (detlint itself and
        // the three bench harnesses).
        assert!(report.files > 50, "suspiciously few files scanned: {}", report.files);
        let budget_text = std::fs::read_to_string(root.join("rust/tools/detlint/waiver-budget.txt"))
            .expect("waiver-budget.txt");
        let budget = parse_budget(&budget_text).expect("budget parses");
        let (ok, rendered) = check(&report, &budget);
        assert!(ok, "detlint check failed:\n{rendered}");
    }

    /// Scan a synthetic tree laid out as `<dir>/<rel>` = file body.
    fn scan_seeded(files: &[(&str, &str)]) -> (bool, String) {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "detlint-seed-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        for (rel, body) in files {
            let path = dir.join(rel);
            std::fs::create_dir_all(path.parent().expect("parent")).expect("mkdir");
            std::fs::write(&path, body).expect("write seed file");
        }
        let mut report = Report::default();
        let res = scan_tree(&dir, "", "", &mut report);
        std::fs::remove_dir_all(&dir).ok();
        res.expect("scan");
        check(&report, &BTreeMap::new())
    }

    /// Acceptance criterion: seeding a synthetic `HashMap` iteration
    /// into a scanned tree produces a failing check with a file:line
    /// diagnostic.
    #[test]
    fn seeded_violation_fails_with_file_line_diagnostic() {
        let (ok, rendered) = scan_seeded(&[(
            "cluster/events.rs",
            "use std::collections::HashMap;\n\
             pub fn drain_routes(route: &mut HashMap<usize, usize>) -> usize {\n\
             let mut n = 0;\n\
             for (_, v) in route.iter() { n += v; }\n\
             n\n\
             }\n",
        )]);
        assert!(!ok, "seeded violation must fail the check");
        assert!(
            rendered.contains("cluster/events.rs:4 [unordered-iter]"),
            "diagnostic must carry file:line, got:\n{rendered}"
        );
    }

    /// Acceptance criterion (unit-mix): a `ttft_ms`-vs-seconds mixup
    /// in a scanned tree fails with a file:line diagnostic.
    #[test]
    fn seeded_unit_mix_fails_with_file_line_diagnostic() {
        let (ok, rendered) = scan_seeded(&[(
            "engine/metrics.rs",
            "pub fn report(ttft_s: f64, itl_ms: f64) -> f64 {\n\
             ttft_s + itl_ms\n\
             }\n",
        )]);
        assert!(!ok, "seeded unit mix must fail the check");
        assert!(
            rendered.contains("engine/metrics.rs:2 [unit-mix]"),
            "diagnostic must carry file:line, got:\n{rendered}"
        );
    }

    /// Acceptance criterion (lossy-cast): a truncating `u64 as u32` in
    /// an accounting module fails with a file:line diagnostic.
    #[test]
    fn seeded_lossy_cast_fails_with_file_line_diagnostic() {
        let (ok, rendered) = scan_seeded(&[(
            "cluster/events.rs",
            "pub fn shipped(kv_bytes: u64) -> u32 {\n\
             kv_bytes as u32\n\
             }\n",
        )]);
        assert!(!ok, "seeded lossy cast must fail the check");
        assert!(
            rendered.contains("cluster/events.rs:2 [lossy-cast]"),
            "diagnostic must carry file:line, got:\n{rendered}"
        );
    }

    /// Acceptance criterion (panic-path): an `.unwrap()` in a serving
    /// hot path fails with a file:line diagnostic.
    #[test]
    fn seeded_panic_path_fails_with_file_line_diagnostic() {
        let (ok, rendered) = scan_seeded(&[(
            "placement/greedy.rs",
            "pub fn best(xs: &[f64]) -> f64 {\n\
             let i = xs.iter().position(|x| *x > 0.0).unwrap();\n\
             xs[i]\n\
             }\n",
        )]);
        assert!(!ok, "seeded panic path must fail the check");
        assert!(
            rendered.contains("placement/greedy.rs:2 [panic-path]"),
            "unwrap diagnostic must carry file:line, got:\n{rendered}"
        );
        assert!(
            rendered.contains("placement/greedy.rs:3 [panic-path]"),
            "non-literal index diagnostic must carry file:line, got:\n{rendered}"
        );
    }

    /// Satellite regression: a stale waiver (annotation with no
    /// matching violation) fails `--check`, it no longer just warns.
    #[test]
    fn stale_waiver_fails_check() {
        let (ok, rendered) = scan_seeded(&[(
            "workload/gen.rs",
            "// detlint: allow(wall-clock) — covers nothing at all\n\
             pub fn f() -> usize { 1 }\n",
        )]);
        assert!(!ok, "stale waiver must fail the check");
        assert!(
            rendered.contains("ERROR: stale waiver workload/gen.rs:1 [wall-clock]"),
            "stale waiver must be reported as an error, got:\n{rendered}"
        );
    }

    /// The per-rule inventory block CI audits is present and counts
    /// active vs waived findings per rule.
    #[test]
    fn per_rule_inventory_summarizes_counts() {
        let (ok, rendered) = scan_seeded(&[(
            "cluster/events.rs",
            "// detlint: allow(panic-path) — seeded: index proven in bounds by test\n\
             pub fn pick(xs: &[f64], i: usize) -> f64 { xs[i] }\n\
             \n\
             \n\
             pub fn pick2(xs: &[f64], i: usize) -> f64 { xs[i] }\n",
        )]);
        assert!(!ok, "one unwaived finding remains");
        assert!(
            rendered.contains("per-rule inventory"),
            "inventory header missing:\n{rendered}"
        );
        assert!(
            rendered.contains("panic-path: 1 active, 1 waived; waivers 1/0 EXCEEDED"),
            "per-rule counts wrong:\n{rendered}"
        );
    }
}
