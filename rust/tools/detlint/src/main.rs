//! `detlint` — determinism & invariant static analysis for the
//! `adapter_serving` crate (DESIGN.md §13).
//!
//! Scans `rust/src/**/*.rs` with a hand-rolled token-level pass and
//! enforces five rules:
//!
//! * `unordered-iter` — no `HashMap`/`HashSet` iteration in
//!   determinism-critical modules;
//! * `wall-clock` — no `Instant::now`/`SystemTime` outside timing
//!   modules;
//! * `float-key` — fingerprint/memo-key code must route floats
//!   through `to_bits()`;
//! * `ambient-entropy` — no `thread::spawn` outside
//!   `util::threadpool`, no unseeded randomness outside `util::rng`;
//! * `deprecated` — no in-crate `#[deprecated]` APIs.
//!
//! Violations are silenced only by an inline
//! `// detlint: allow(<rule>) — <reason>` waiver on the offending
//! line or up to two lines above; every waiver must carry a reason
//! and the per-rule waiver count is capped by `waiver-budget.txt`.
//!
//! ```text
//! cargo run -p detlint -- --check            # CI gate: non-zero exit on any finding
//! cargo run -p detlint -- --waivers          # print the waiver inventory only
//! cargo run -p detlint -- --root DIR --budget FILE
//! ```

mod config;
mod lexer;
mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A violation tagged with its file, plus the waiver that covers it
/// (if any).
struct Finding {
    rel: String,
    violation: rules::Violation,
    waived_by: Option<rules::Waiver>,
}

/// Full scan result over the tree.
#[derive(Default)]
struct Report {
    findings: Vec<Finding>,
    /// All waivers seen, as `(rel, waiver, used)`.
    waivers: Vec<(String, rules::Waiver, bool)>,
    files: usize,
}

fn scan_tree(src_root: &Path) -> Result<Report, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();

    let mut report = Report::default();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let toks = lexer::lex(&src);
        let module = config::module_path(&rel);
        let violations = rules::analyze(&module, &rel, &toks);
        let waivers = rules::parse_waivers(&toks);
        let mut used = vec![false; waivers.len()];
        for v in violations {
            let hit = waivers
                .iter()
                .enumerate()
                .find(|(_, w)| rules::waiver_covers(w, v.rule, v.line));
            let waived_by = hit.map(|(i, w)| {
                used[i] = true;
                w.clone()
            });
            report.findings.push(Finding { rel: rel.clone(), violation: v, waived_by });
        }
        for (w, u) in waivers.into_iter().zip(used) {
            report.waivers.push((rel.clone(), w, u));
        }
        report.files += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Parse `waiver-budget.txt`: `<rule-id> <max-count>` per line, `#`
/// comments.  Rules absent from the file have budget 0.
fn parse_budget(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut out = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(rule), Some(count)) = (it.next(), it.next()) else {
            return Err(format!("budget line {}: expected `<rule> <count>`", ln + 1));
        };
        if !config::RULE_IDS.contains(&rule) {
            return Err(format!("budget line {}: unknown rule `{rule}`", ln + 1));
        }
        let n: usize =
            count.parse().map_err(|e| format!("budget line {}: {e}", ln + 1))?;
        out.insert(rule.to_string(), n);
    }
    Ok(out)
}

/// Everything `--check` enforces, as (ok, rendered report).
fn check(report: &Report, budget: &BTreeMap<String, usize>) -> (bool, String) {
    let mut out = String::new();
    let mut ok = true;

    let active: Vec<&Finding> =
        report.findings.iter().filter(|f| f.waived_by.is_none()).collect();
    if active.is_empty() {
        out.push_str(&format!(
            "detlint: {} files scanned, 0 unwaivered violations\n",
            report.files
        ));
    } else {
        ok = false;
        out.push_str(&format!("detlint: {} violation(s):\n", active.len()));
        for f in &active {
            out.push_str(&format!(
                "  rust/src/{}:{} [{}] {}\n",
                f.rel, f.violation.line, f.violation.rule, f.violation.msg
            ));
        }
    }

    // Waiver inventory, with reasons — the audited budget.
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    let mut no_reason = 0usize;
    out.push_str("waiver inventory:\n");
    for (rel, w, used) in &report.waivers {
        if !used {
            out.push_str(&format!(
                "  warning: stale waiver rust/src/{rel}:{} [{}] covers nothing\n",
                w.line, w.rule
            ));
            continue;
        }
        if w.reason.is_empty() {
            ok = false;
            no_reason += 1;
            out.push_str(&format!(
                "  ERROR: waiver without reason at rust/src/{rel}:{} [{}]\n",
                w.line, w.rule
            ));
            continue;
        }
        *counts.entry(w.rule.as_str()).or_default() += 1;
        out.push_str(&format!("  rust/src/{rel}:{} [{}] — {}\n", w.line, w.rule, w.reason));
    }
    if report.waivers.iter().all(|(_, _, used)| !used) {
        out.push_str("  (none)\n");
    }
    if no_reason > 0 {
        out.push_str(&format!("{no_reason} waiver(s) missing a reason\n"));
    }

    out.push_str("waiver budget:\n");
    for rule in config::RULE_IDS {
        let have = counts.get(rule).copied().unwrap_or(0);
        let max = budget.get(rule).copied().unwrap_or(0);
        let status = if have > max { "EXCEEDED" } else { "ok" };
        out.push_str(&format!("  {rule}: {have}/{max} {status}\n"));
        if have > max {
            ok = false;
        }
    }
    (ok, out)
}

fn default_root() -> PathBuf {
    // tools/detlint sits at <repo>/rust/tools/detlint.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..").canonicalize().unwrap_or_else(|_| {
        PathBuf::from(".")
    })
}

fn main() -> ExitCode {
    let mut check_mode = false;
    let mut waivers_only = false;
    let mut root = default_root();
    let mut budget_path: Option<PathBuf> = None;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => check_mode = true,
            "--waivers" => waivers_only = true,
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => return usage("--root needs a directory"),
            },
            "--budget" => match args.next() {
                Some(f) => budget_path = Some(PathBuf::from(f)),
                None => return usage("--budget needs a file"),
            },
            "--help" | "-h" => {
                println!(
                    "detlint [--check] [--waivers] [--root DIR] [--budget FILE]\n\
                     determinism lint over rust/src — see DESIGN.md §13"
                );
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    let src_root = root.join("rust/src");
    if !src_root.is_dir() {
        eprintln!("detlint: source root {} not found", src_root.display());
        return ExitCode::from(2);
    }
    let report = match scan_tree(&src_root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };

    if waivers_only {
        for (rel, w, used) in &report.waivers {
            let mark = if *used { "" } else { " (stale)" };
            println!("rust/src/{rel}:{} [{}]{} — {}", w.line, w.rule, mark, w.reason);
        }
        return ExitCode::SUCCESS;
    }

    let budget_file =
        budget_path.unwrap_or_else(|| root.join("rust/tools/detlint/waiver-budget.txt"));
    let budget = match std::fs::read_to_string(&budget_file) {
        Ok(text) => match parse_budget(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("detlint: {}: {e}", budget_file.display());
                return ExitCode::from(2);
            }
        },
        Err(e) if check_mode => {
            eprintln!("detlint: budget file {}: {e}", budget_file.display());
            return ExitCode::from(2);
        }
        Err(_) => BTreeMap::new(),
    };

    let (ok, rendered) = check(&report, &budget);
    print!("{rendered}");
    if check_mode && !ok {
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("detlint: {msg} (try --help)");
    ExitCode::from(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_parsing() {
        let b = parse_budget("# comment\nwall-clock 9\nunordered-iter 1 # inline\n").unwrap();
        assert_eq!(b.get("wall-clock"), Some(&9));
        assert_eq!(b.get("unordered-iter"), Some(&1));
        assert!(parse_budget("no-such-rule 3\n").is_err());
        assert!(parse_budget("wall-clock\n").is_err());
    }

    /// The CI gate as a tier-1 test: the real tree must scan clean —
    /// zero unwaivered violations, every waiver reasoned and within
    /// the checked-in budget.
    #[test]
    fn repo_tree_is_clean_under_budget() {
        let root = default_root();
        let src_root = root.join("rust/src");
        assert!(src_root.is_dir(), "source root missing: {}", src_root.display());
        let report = scan_tree(&src_root).expect("scan");
        assert!(report.files > 20, "suspiciously few files scanned: {}", report.files);
        let budget_text = std::fs::read_to_string(root.join("rust/tools/detlint/waiver-budget.txt"))
            .expect("waiver-budget.txt");
        let budget = parse_budget(&budget_text).expect("budget parses");
        let (ok, rendered) = check(&report, &budget);
        assert!(ok, "detlint check failed:\n{rendered}");
    }

    /// Acceptance criterion: seeding a synthetic `HashMap` iteration
    /// into a scanned tree produces a failing check with a file:line
    /// diagnostic.
    #[test]
    fn seeded_violation_fails_with_file_line_diagnostic() {
        let dir = std::env::temp_dir().join(format!("detlint-seed-{}", std::process::id()));
        let cluster = dir.join("cluster");
        std::fs::create_dir_all(&cluster).expect("mkdir");
        std::fs::write(
            cluster.join("events.rs"),
            "use std::collections::HashMap;\n\
             pub fn drain_routes(route: &mut HashMap<usize, usize>) -> usize {\n\
             let mut n = 0;\n\
             for (_, v) in route.iter() { n += v; }\n\
             n\n\
             }\n",
        )
        .expect("write seed file");
        let report = scan_tree(&dir).expect("scan");
        let (ok, rendered) = check(&report, &BTreeMap::new());
        std::fs::remove_dir_all(&dir).ok();
        assert!(!ok, "seeded violation must fail the check");
        assert!(
            rendered.contains("cluster/events.rs:4 [unordered-iter]"),
            "diagnostic must carry file:line, got:\n{rendered}"
        );
    }
}
