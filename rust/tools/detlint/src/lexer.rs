//! A minimal hand-rolled Rust lexer — just enough fidelity for the
//! determinism ruleset: identifiers, punctuation (multi-char operators
//! kept whole so `==`/`::` never read as two tokens), numeric literals
//! with float-ness, strings/chars/lifetimes, and comments (kept as
//! tokens so waiver annotations can be recovered with their line).
//!
//! Fidelity limits are deliberate: no macro expansion, no type
//! inference.  The rule pass compensates with per-file binding tracking
//! (see `rules.rs`); DESIGN.md §13 documents the blind spots.

/// Token class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`for`, `let`, `HashMap`, …).
    Ident,
    /// Operator / delimiter, multi-char operators intact (`::`, `==`).
    Punct,
    /// Integer literal (including hex/oct/bin).
    Int,
    /// Float literal (has `.`, exponent, or `f32`/`f64` suffix).
    Float,
    /// String literal (plain, raw or byte; contents dropped).
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Line or block comment, text preserved for waiver parsing.
    Comment,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token class.
    pub kind: Kind,
    /// Source text (comments keep full text; strings are dropped).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

impl Tok {
    /// Is this an identifier with exactly this text?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }

    /// Is this punctuation with exactly this text?
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == Kind::Punct && self.text == s
    }
}

const PUNCT3: [&str; 5] = ["..=", "...", "<<=", ">>=", "=>>"];
const PUNCT2: [&str; 19] = [
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..", "+=", "-=", "*=", "/=", "%=",
    "^=", "&=", "|=", "<<",
];

/// Lex `src` into tokens.  Never fails: unrecognized bytes become
/// single-char punctuation, unterminated literals run to end of input.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut out: Vec<Tok> = Vec::new();

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.push(Tok { kind: Kind::Comment, text: b[start..i].iter().collect(), line });
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let tok_line = line;
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Tok {
                kind: Kind::Comment,
                text: b[start..i].iter().collect(),
                line: tok_line,
            });
            continue;
        }
        // Raw / byte strings: r"…", r#"…"#, br"…", b"…".
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            let is_raw = j > i + usize::from(c == 'b' && i + 1 < n && b[i + 1] == 'r') || c == 'r';
            if j < n && b[j] == '"' && (hashes > 0 || is_raw || c == 'b') {
                // Raw string: scan for `"` followed by `hashes` hashes.
                // (For b"…" with hashes == 0 this is exact too, except
                // escapes — a `\"` inside would end early; byte strings
                // with escaped quotes are absent from this tree.)
                let tok_line = line;
                i = j + 1;
                loop {
                    if i >= n {
                        break;
                    }
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if hashes == 0 && b[i] == '\\' && c == 'b' {
                        i += 2;
                        continue;
                    }
                    if b[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            i += 1 + hashes;
                            break;
                        }
                    }
                    i += 1;
                }
                out.push(Tok { kind: Kind::Str, text: String::new(), line: tok_line });
                continue;
            }
            // Byte char b'x'.
            if c == 'b' && i + 1 < n && b[i + 1] == '\'' {
                let tok_line = line;
                i += 2; // past `b` and the opening quote
                if i < n && b[i] == '\\' {
                    i += 2; // past the backslash and the escaped char
                    while i < n && b[i] != '\'' {
                        i += 1;
                    }
                    i += 1;
                } else {
                    i += 1; // the char
                    if i < n && b[i] == '\'' {
                        i += 1;
                    }
                }
                out.push(Tok { kind: Kind::Char, text: String::new(), line: tok_line });
                continue;
            }
            // Raw identifier r#ident.
            if c == 'r' && i + 1 < n && b[i + 1] == '#' && i + 2 < n && is_ident_start(b[i + 2]) {
                let start = i + 2;
                i += 2;
                while i < n && is_ident_continue(b[i]) {
                    i += 1;
                }
                out.push(Tok { kind: Kind::Ident, text: b[start..i].iter().collect(), line });
                continue;
            }
            // else: plain identifier starting with r/b — fall through.
        }
        // Plain string.
        if c == '"' {
            let tok_line = line;
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '\n' {
                    line += 1;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            out.push(Tok { kind: Kind::Str, text: String::new(), line: tok_line });
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            let tok_line = line;
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: '\n', '\u{…}', '\'', '\\'.
                i += 3; // opening quote, backslash, escaped char
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
                out.push(Tok { kind: Kind::Char, text: String::new(), line: tok_line });
            } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // 'x'
                i += 3;
                out.push(Tok { kind: Kind::Char, text: String::new(), line: tok_line });
            } else {
                // Lifetime.
                let start = i + 1;
                i += 1;
                while i < n && is_ident_continue(b[i]) {
                    i += 1;
                }
                out.push(Tok {
                    kind: Kind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line: tok_line,
                });
            }
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let start = i;
            let mut is_float = false;
            if c == '0' && i + 1 < n && matches!(b[i + 1], 'x' | 'o' | 'b') {
                i += 2;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                    i += 1;
                }
                if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                    is_float = true;
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                }
                if i < n && (b[i] == 'e' || b[i] == 'E') {
                    let exp_ok = i + 1 < n
                        && (b[i + 1].is_ascii_digit()
                            || ((b[i + 1] == '+' || b[i + 1] == '-')
                                && i + 2 < n
                                && b[i + 2].is_ascii_digit()));
                    if exp_ok {
                        is_float = true;
                        i += 1;
                        if b[i] == '+' || b[i] == '-' {
                            i += 1;
                        }
                        while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                            i += 1;
                        }
                    }
                }
                // Type suffix (f64, u32, usize, …).
                let sfx = i;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                if b[sfx..i].starts_with(&['f']) {
                    is_float = true;
                }
            }
            out.push(Tok {
                kind: if is_float { Kind::Float } else { Kind::Int },
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            out.push(Tok { kind: Kind::Ident, text: b[start..i].iter().collect(), line });
            continue;
        }
        // Punctuation, longest match first.
        let rest3: String = b[i..n.min(i + 3)].iter().collect();
        if PUNCT3.contains(&rest3.as_str()) {
            out.push(Tok { kind: Kind::Punct, text: rest3, line });
            i += 3;
            continue;
        }
        let rest2: String = b[i..n.min(i + 2)].iter().collect();
        if PUNCT2.contains(&rest2.as_str()) {
            out.push(Tok { kind: Kind::Punct, text: rest2, line });
            i += 2;
            continue;
        }
        out.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn operators_stay_whole() {
        let toks = lex("a == b != c :: d");
        let ops: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Punct)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(ops, vec!["==", "!=", "::"]);
    }

    #[test]
    fn floats_vs_ints_vs_ranges() {
        let toks = kinds("1.5 0..4 2e3 7usize 3.0f64 0xff");
        let nums: Vec<(Kind, &str)> = toks
            .iter()
            .filter(|(k, _)| matches!(k, Kind::Int | Kind::Float))
            .map(|(k, t)| (*k, t.as_str()))
            .collect();
        assert_eq!(
            nums,
            vec![
                (Kind::Float, "1.5"),
                (Kind::Int, "0"),
                (Kind::Int, "4"),
                (Kind::Float, "2e3"),
                (Kind::Int, "7usize"),
                (Kind::Float, "3.0f64"),
                (Kind::Int, "0xff"),
            ]
        );
    }

    #[test]
    fn lifetimes_and_chars() {
        let toks = lex("&'a str; let c = 'x'; let nl = '\\n';");
        assert!(toks.iter().any(|t| t.kind == Kind::Lifetime && t.text == "a"));
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Char).count(), 2);
    }

    #[test]
    fn comments_keep_text_and_lines() {
        let src = "let a = 1;\n// detlint: allow(wall-clock) — reporting only\nlet b = 2;";
        let toks = lex(src);
        let c = toks.iter().find(|t| t.kind == Kind::Comment).unwrap();
        assert_eq!(c.line, 2);
        assert!(c.text.contains("detlint: allow(wall-clock)"));
        assert_eq!(toks.iter().filter(|t| t.is_ident("let")).count(), 3);
    }

    #[test]
    fn strings_do_not_leak_tokens() {
        let toks = lex(r#"let s = "HashMap::iter() == 1.5"; let r = r"x\"; "#);
        assert!(!toks.iter().any(|t| t.is_ident("HashMap")));
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Str).count(), 2);
    }

    #[test]
    fn escaped_quote_char_literals() {
        let toks = lex("let q = '\\''; let b = b'\\''; let s = '\\\\'; let x = 1;");
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Char).count(), 3);
        assert!(!toks.iter().any(|t| t.kind == Kind::Lifetime));
        assert!(toks.iter().any(|t| t.is_ident("x")));
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* b */ c */ let x = 1;");
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Comment).count(), 1);
        assert!(toks.iter().any(|t| t.is_ident("let")));
    }
}
