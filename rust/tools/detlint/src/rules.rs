//! The determinism rule pass: given one file's tokens and its module
//! path, emit violations.  Waivers are parsed here too; *matching*
//! waivers to violations is the driver's job (`main.rs`) so the
//! inventory can be reported globally.
//!
//! All passes are per-file and token-level.  Type information is
//! approximated by tracked bindings: an identifier declared as
//! `HashMap`/`HashSet` (or `f64`/`f32` in fingerprint files) anywhere
//! in the file taints every later use of that name.  That
//! over-approximates (name collisions) and under-approximates (values
//! returned from functions) — both are acceptable for a lint whose
//! escape hatch is a one-line waiver.

use crate::config;
use crate::lexer::{Kind, Tok};

/// One rule violation at a source line.
#[derive(Debug, Clone)]
pub struct Violation {
    /// 1-based source line.
    pub line: u32,
    /// Rule id (one of `config::RULE_IDS`).
    pub rule: &'static str,
    /// Human-readable diagnostic.
    pub msg: String,
}

/// One parsed `// detlint: allow(<rule>) — <reason>` annotation.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 1-based line of the comment.
    pub line: u32,
    /// Waived rule id.
    pub rule: String,
    /// Justification text after the rule id (may be empty — the
    /// driver rejects empty reasons).
    pub reason: String,
}

const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const INT_TYPES: [&str; 12] =
    ["u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"];

const ENTROPY_IDENTS: [&str; 4] = ["RandomState", "thread_rng", "from_entropy", "OsRng"];

/// Tokens transparently skipped when walking back from a type name to
/// the binding it annotates (`resident: Mutex<HashMap<…>>`).
fn is_back_skip(t: &Tok) -> bool {
    if t.kind == Kind::Lifetime {
        return true;
    }
    matches!(
        t.text.as_str(),
        "::" | "<"
            | ">"
            | "&"
            | "("
            | ","
            | "="
            | "mut"
            | "dyn"
            | "std"
            | "collections"
            | "hash_map"
            | "hash_set"
            | "btree_map"
            | "Mutex"
            | "RwLock"
            | "Option"
            | "Vec"
            | "Box"
            | "Arc"
            | "Rc"
    )
}

/// Names bound (let / field / param) to any of `type_names` in this
/// file, found by back-walking from each type-name occurrence.
fn tracked_bindings(code: &[&Tok], type_names: &[&str]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.kind != Kind::Ident || !type_names.contains(&t.text.as_str()) {
            continue;
        }
        let mut j = i;
        let mut steps = 0;
        while j > 0 && is_back_skip(code[j - 1]) && steps < 16 {
            j -= 1;
            steps += 1;
        }
        if j == 0 {
            continue;
        }
        let at = code[j - 1];
        // `name: Type` — field, param, or annotated let.
        if at.is_punct(":") && j >= 2 && code[j - 2].kind == Kind::Ident {
            names.push(code[j - 2].text.clone());
            continue;
        }
        // `let [mut] name = Type::new()` — back-walk already skipped
        // the `=` and `mut`, leaving us at `name`.
        if at.kind == Kind::Ident
            && j >= 2
            && (code[j - 2].is_ident("let")
                || (code[j - 2].is_ident("mut") && j >= 3 && code[j - 3].is_ident("let")))
        {
            names.push(at.text.clone());
        }
    }
    names.sort_unstable();
    names.dedup();
    names
}

/// Run all five rules over one file.
///
/// * `module` — module path (`cluster::events`), see [`config::module_path`];
/// * `rel` — path relative to the source root, forward slashes
///   (drives the R3 fingerprint-file scope);
/// * `toks` — full token stream including comments.
pub fn analyze(module: &str, rel: &str, toks: &[Tok]) -> Vec<Violation> {
    let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();
    let mut out: Vec<Violation> = Vec::new();

    let critical = config::module_in(&config::CRITICAL_MODULES, module);
    let clock_ok = config::module_in(&config::WALL_CLOCK_ALLOW, module);
    let spawn_ok = config::module_in(&config::SPAWN_ALLOW, module);
    let rng_ok = config::module_in(&config::RNG_ALLOW, module);
    let fingerprint_file = config::FLOAT_KEY_FILES.iter().any(|f| rel.ends_with(f));

    // ---- R1: unordered iteration over hash collections -------------
    if critical {
        let hashed = tracked_bindings(&code, &["HashMap", "HashSet"]);
        let is_hashed = |t: &Tok| t.kind == Kind::Ident && hashed.iter().any(|n| *n == t.text);
        for (i, t) in code.iter().enumerate() {
            // `map.iter()`, `self.map.keys()`, `map.drain()`, …
            if is_hashed(t)
                && i + 2 < code.len()
                && code[i + 1].is_punct(".")
                && code[i + 2].kind == Kind::Ident
                && ITER_METHODS.contains(&code[i + 2].text.as_str())
            {
                out.push(Violation {
                    line: t.line,
                    rule: "unordered-iter",
                    msg: format!(
                        "`{}.{}()` iterates a hash collection in determinism-critical \
                         module `{}`; use BTreeMap/BTreeSet or sort first",
                        t.text, code[i + 2].text, module
                    ),
                });
            }
            // `for x in &map { … }` / `for x in map { … }`
            if t.is_ident("in") {
                let mut k = i + 1;
                while k < code.len() && (code[k].is_punct("&") || code[k].is_ident("mut")) {
                    k += 1;
                }
                if k + 1 < code.len() && is_hashed(code[k]) && code[k + 1].is_punct("{") {
                    out.push(Violation {
                        line: code[k].line,
                        rule: "unordered-iter",
                        msg: format!(
                            "`for … in {}` iterates a hash collection in \
                             determinism-critical module `{}`",
                            code[k].text, module
                        ),
                    });
                }
            }
        }
    }

    // ---- R2: wall clocks outside timing modules --------------------
    if !clock_ok {
        for (i, t) in code.iter().enumerate() {
            if t.is_ident("Instant")
                && i + 2 < code.len()
                && code[i + 1].is_punct("::")
                && code[i + 2].is_ident("now")
            {
                out.push(Violation {
                    line: t.line,
                    rule: "wall-clock",
                    msg: format!(
                        "`Instant::now()` outside timing allowlist (module `{module}`); \
                         wall time must not influence simulated state"
                    ),
                });
            }
            if t.is_ident("SystemTime") {
                out.push(Violation {
                    line: t.line,
                    rule: "wall-clock",
                    msg: format!("`SystemTime` outside timing allowlist (module `{module}`)"),
                });
            }
        }
    }

    // ---- R3: raw floats in memo-key / fingerprint code -------------
    if fingerprint_file {
        let floats = tracked_bindings(&code, &["f64", "f32"]);
        let is_float =
            |t: &Tok| t.kind == Kind::Float || floats.iter().any(|n| t.is_ident(n.as_str()));
        for (i, t) in code.iter().enumerate() {
            if (t.is_punct("==") || t.is_punct("!="))
                && i > 0
                && i + 1 < code.len()
                && (is_float(code[i - 1]) || is_float(code[i + 1]))
            {
                out.push(Violation {
                    line: t.line,
                    rule: "float-key",
                    msg: "float comparison in fingerprint path; compare `to_bits()` instead"
                        .to_string(),
                });
            }
            if t.is_ident("as")
                && i > 0
                && i + 1 < code.len()
                && INT_TYPES.contains(&code[i + 1].text.as_str())
                && is_float(code[i - 1])
            {
                out.push(Violation {
                    line: t.line,
                    rule: "float-key",
                    msg: format!(
                        "float → `{}` cast in fingerprint path; use `to_bits()` for a \
                         total, lossless key",
                        code[i + 1].text
                    ),
                });
            }
        }
    }

    // ---- R4: ambient entropy (threads, unseeded randomness) --------
    for (i, t) in code.iter().enumerate() {
        if !spawn_ok
            && t.is_ident("thread")
            && i + 2 < code.len()
            && code[i + 1].is_punct("::")
            && code[i + 2].is_ident("spawn")
        {
            out.push(Violation {
                line: t.line,
                rule: "ambient-entropy",
                msg: format!(
                    "`thread::spawn` outside util::threadpool (module `{module}`); \
                     ad-hoc threads make completion order a scheduling artifact"
                ),
            });
        }
        if !rng_ok && t.kind == Kind::Ident && ENTROPY_IDENTS.contains(&t.text.as_str()) {
            out.push(Violation {
                line: t.line,
                rule: "ambient-entropy",
                msg: format!(
                    "`{}` outside util::rng (module `{module}`); all randomness must \
                     be seeded",
                    t.text
                ),
            });
        }
    }

    // ---- R5: deprecated APIs must not exist or be used -------------
    for (i, t) in code.iter().enumerate() {
        if t.is_ident("deprecated") {
            let suppressed =
                i >= 2 && code[i - 1].is_punct("(") && code[i - 2].is_ident("allow");
            out.push(Violation {
                line: t.line,
                rule: "deprecated",
                msg: if suppressed {
                    "`#[allow(deprecated)]` hides use of a deprecated API".to_string()
                } else {
                    "`deprecated` marker: in-crate deprecated APIs must be removed, \
                     not accumulated"
                        .to_string()
                },
            });
        }
    }

    out.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    out.dedup_by(|a, b| a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    out
}

/// Extract waiver annotations from a file's comment tokens.
pub fn parse_waivers(toks: &[Tok]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks {
        if t.kind != Kind::Comment {
            continue;
        }
        let Some(at) = t.text.find("detlint:") else { continue };
        let rest = &t.text[at + "detlint:".len()..];
        let Some(open) = rest.find("allow(") else { continue };
        let after = &rest[open + "allow(".len()..];
        let Some(close) = after.find(')') else { continue };
        let rule = after[..close].trim().to_string();
        let reason = after[close + 1..]
            .trim_start_matches([' ', '\t', '—', '–', '-', ':'])
            .trim_end_matches(['*', '/', ' '])
            .trim()
            .to_string();
        out.push(Waiver { line: t.line, rule, reason });
    }
    out
}

/// Does `w` cover a violation of `rule` at `line`?  A waiver applies
/// on its own line or up to two lines above (so `#[allow(...)]`
/// attribute lines can sit between the comment and the code).
pub fn waiver_covers(w: &Waiver, rule: &str, line: u32) -> bool {
    w.rule == rule && w.line <= line && line <= w.line + 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn run(module: &str, rel: &str, src: &str) -> Vec<Violation> {
        analyze(module, rel, &lex(src))
    }

    #[test]
    fn seeded_hashmap_iteration_in_cluster_events_is_flagged() {
        // The acceptance-criteria scenario: a synthetic violation in
        // cluster/events.rs must produce a file:line diagnostic.
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n\
                   let mut route: HashMap<usize, usize> = HashMap::new();\n\
                   route.insert(1, 2);\n\
                   for (k, v) in route.iter() { println!(\"{k}{v}\"); }\n\
                   }\n";
        let v = run("cluster::events", "cluster/events.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unordered-iter");
        assert_eq!(v[0].line, 5);
    }

    #[test]
    fn for_in_ref_over_hashset_is_flagged() {
        let src = "use std::collections::HashSet;\n\
                   fn f(placed: &HashSet<usize>) {\n\
                   for p in placed { let _ = p; }\n\
                   }\n";
        let v = run("placement::replan", "placement/replan.rs", src);
        assert!(v.iter().any(|x| x.rule == "unordered-iter" && x.line == 3), "{v:?}");
    }

    #[test]
    fn lookup_only_hashmap_is_clean_and_noncritical_modules_ignored() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<usize, usize>) -> Option<&usize> { m.get(&1) }\n";
        assert!(run("cluster::events", "cluster/events.rs", src).is_empty());
        let iterating = "use std::collections::HashMap;\n\
                         fn f(m: &HashMap<usize, usize>) { for x in m.iter() { let _ = x; } }\n";
        assert!(run("experiments::fleet", "experiments/fleet.rs", iterating).is_empty());
    }

    #[test]
    fn btreemap_iteration_is_clean() {
        let src = "use std::collections::BTreeMap;\n\
                   fn f(m: &BTreeMap<usize, usize>) { for x in m.iter() { let _ = x; } }\n";
        assert!(run("cluster::events", "cluster/events.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_scoping() {
        let src = "use std::time::Instant;\nfn f() { let _t = Instant::now(); }\n";
        let v = run("engine::kv", "engine/kv.rs", src);
        assert!(v.iter().any(|x| x.rule == "wall-clock" && x.line == 2), "{v:?}");
        assert!(run("util::bench", "util/bench.rs", src).is_empty());
        assert!(run("experiments::fleet", "experiments/fleet.rs", src).is_empty());
        assert!(run("engine", "engine/mod.rs", src).is_empty());
    }

    #[test]
    fn float_key_rules_fire_only_in_fingerprint_files() {
        let src = "fn key(v: f64) -> u64 { if v == 0.0 { 0 } else { v as u64 } }\n";
        let v = run("placement::estimator", "placement/estimator.rs", src);
        assert_eq!(v.iter().filter(|x| x.rule == "float-key").count(), 2, "{v:?}");
        assert!(run("ml::features", "ml/features.rs", src).is_empty());
    }

    #[test]
    fn ambient_entropy_and_deprecated() {
        let spawn = "fn f() { std::thread::spawn(|| {}); }\n";
        assert!(!run("cluster::mod_", "cluster/x.rs", spawn).is_empty());
        assert!(run("util::threadpool", "util/threadpool.rs", spawn).is_empty());
        let dep = "#[deprecated(note = \"gone\")]\nfn old() {}\n";
        assert!(run("config", "config.rs", dep).iter().any(|x| x.rule == "deprecated"));
        let sup = "#[allow(deprecated)]\nfn f() {}\n";
        let v = run("config", "config.rs", sup);
        assert!(v.iter().any(|x| x.rule == "deprecated" && x.msg.contains("hides")));
    }

    #[test]
    fn waivers_parse_and_cover_nearby_lines() {
        let src = "// detlint: allow(unordered-iter) — snapshot is sorted immediately after\n\
                   #[allow(clippy::disallowed_types)]\n\
                   fn f() {}\n";
        let ws = parse_waivers(&lex(src));
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].rule, "unordered-iter");
        assert_eq!(ws[0].reason, "snapshot is sorted immediately after");
        assert!(waiver_covers(&ws[0], "unordered-iter", 1));
        assert!(waiver_covers(&ws[0], "unordered-iter", 3));
        assert!(!waiver_covers(&ws[0], "unordered-iter", 4));
        assert!(!waiver_covers(&ws[0], "wall-clock", 1));
    }

    #[test]
    fn waiver_reason_may_be_empty_for_driver_to_reject() {
        let ws = parse_waivers(&lex("// detlint: allow(wall-clock)\n"));
        assert_eq!(ws.len(), 1);
        assert!(ws[0].reason.is_empty());
    }
}
