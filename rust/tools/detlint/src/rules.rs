//! The determinism rule pass: given one file's tokens and its module
//! path, emit violations.  Waivers are parsed here too; *matching*
//! waivers to violations is the driver's job (`main.rs`) so the
//! inventory can be reported globally.
//!
//! All passes are per-file and token-level.  Type information is
//! approximated by tracked bindings: an identifier declared as
//! `HashMap`/`HashSet` (or `f64`/`f32` in fingerprint files) anywhere
//! in the file taints every later use of that name.  That
//! over-approximates (name collisions) and under-approximates (values
//! returned from functions) — both are acceptable for a lint whose
//! escape hatch is a one-line waiver.

use crate::config;
use crate::lexer::{Kind, Tok};

/// One rule violation at a source line.
#[derive(Debug, Clone)]
pub struct Violation {
    /// 1-based source line.
    pub line: u32,
    /// Rule id (one of `config::RULE_IDS`).
    pub rule: &'static str,
    /// Human-readable diagnostic.
    pub msg: String,
}

/// One parsed waiver annotation: a `detlint` comment whose `allow`
/// clause names the waived rule, followed by a dash and a reason.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 1-based line of the comment.
    pub line: u32,
    /// Waived rule id.
    pub rule: String,
    /// Justification text after the rule id (may be empty — the
    /// driver rejects empty reasons).
    pub reason: String,
}

const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const INT_TYPES: [&str; 12] =
    ["u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"];

const ENTROPY_IDENTS: [&str; 4] = ["RandomState", "thread_rng", "from_entropy", "OsRng"];

/// Numeric types the lossy-cast pass reasons about.
const FLOAT_TYPES: [&str; 2] = ["f64", "f32"];

/// Identifiers that may legitimately precede an index bracket without
/// the bracket being an index expression (`&mut [T]`, `for x in [..]`,
/// `x as [..]` never exists, slice patterns, …).
const NON_INDEX_PREV: [&str; 14] = [
    "mut", "in", "return", "dyn", "else", "match", "if", "while", "loop", "break", "continue",
    "move", "static", "const",
];

/// Inclusive line ranges covered by `#[cfg(test)]`-gated items.  The
/// correctness rules (`unit-mix`, `lossy-cast`, `panic-path`) target
/// production hot paths only — tests unwrap and index freely by
/// design.  The determinism rules still apply inside tests (a
/// hash-order iteration in a test flakes the suite the same way).
pub fn test_line_ranges(code: &[&Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i + 6 < n {
        let is_cfg_test = code[i].is_punct("#")
            && code[i + 1].is_punct("[")
            && code[i + 2].is_ident("cfg")
            && code[i + 3].is_punct("(")
            && code[i + 4].is_ident("test")
            && code[i + 5].is_punct(")")
            && code[i + 6].is_punct("]");
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let start = code[i].line;
        let mut j = i + 7;
        // Skip any further attributes between the cfg and the item.
        while j + 1 < n && code[j].is_punct("#") && code[j + 1].is_punct("[") {
            let mut depth = 0usize;
            j += 1;
            while j < n {
                if code[j].is_punct("[") {
                    depth += 1;
                } else if code[j].is_punct("]") {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // The gated item ends at the first `;` (use/const) or at the
        // matching brace of its first `{` (mod/fn/impl).
        let mut end = start;
        while j < n {
            if code[j].is_punct(";") {
                end = code[j].line;
                j += 1;
                break;
            }
            if code[j].is_punct("{") {
                let mut depth = 0usize;
                while j < n {
                    if code[j].is_punct("{") {
                        depth += 1;
                    } else if code[j].is_punct("}") {
                        depth -= 1;
                        if depth == 0 {
                            end = code[j].line;
                            break;
                        }
                    }
                    j += 1;
                }
                j += 1;
                break;
            }
            j += 1;
        }
        out.push((start, end.max(start)));
        i = j.max(i + 1);
    }
    out
}

fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Tokens transparently skipped when walking back from a type name to
/// the binding it annotates (`resident: Mutex<HashMap<…>>`).
fn is_back_skip(t: &Tok) -> bool {
    if t.kind == Kind::Lifetime {
        return true;
    }
    matches!(
        t.text.as_str(),
        "::" | "<"
            | ">"
            | "&"
            | "("
            | ","
            | "="
            | "mut"
            | "dyn"
            | "std"
            | "collections"
            | "hash_map"
            | "hash_set"
            | "btree_map"
            | "Mutex"
            | "RwLock"
            | "Option"
            | "Vec"
            | "Box"
            | "Arc"
            | "Rc"
    )
}

/// Names bound (let / field / param) to any of `type_names` in this
/// file, found by back-walking from each type-name occurrence.
fn tracked_bindings(code: &[&Tok], type_names: &[&str]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.kind != Kind::Ident || !type_names.contains(&t.text.as_str()) {
            continue;
        }
        let mut j = i;
        let mut steps = 0;
        while j > 0 && is_back_skip(code[j - 1]) && steps < 16 {
            j -= 1;
            steps += 1;
        }
        if j == 0 {
            continue;
        }
        let at = code[j - 1];
        // `name: Type` — field, param, or annotated let.
        if at.is_punct(":") && j >= 2 && code[j - 2].kind == Kind::Ident {
            names.push(code[j - 2].text.clone());
            continue;
        }
        // `let [mut] name = Type::new()` — back-walk already skipped
        // the `=` and `mut`, leaving us at `name`.
        if at.kind == Kind::Ident
            && j >= 2
            && (code[j - 2].is_ident("let")
                || (code[j - 2].is_ident("mut") && j >= 3 && code[j - 3].is_ident("let")))
        {
            names.push(at.text.clone());
        }
    }
    names.sort_unstable();
    names.dedup();
    names
}

/// Per-file `name → numeric type` approximation for the lossy-cast
/// pass, built by the same back-walk as [`tracked_bindings`].  A name
/// bound to two different numeric types in one file is ambiguous and
/// dropped (the lint stays quiet rather than guessing).
fn typed_bindings(code: &[&Tok]) -> Vec<(String, &'static str)> {
    let mut pairs: Vec<(String, &'static str)> = Vec::new();
    for ty in INT_TYPES.iter().chain(FLOAT_TYPES.iter()) {
        for name in tracked_bindings(code, &[ty]) {
            pairs.push((name, ty));
        }
    }
    pairs.sort();
    pairs.dedup();
    let mut out: Vec<(String, &'static str)> = Vec::new();
    for (name, ty) in pairs {
        match out.last_mut() {
            // Same name under two types: ambiguous, poison the entry.
            Some((last, lt)) if *last == name => *lt = "?",
            _ => out.push((name, ty)),
        }
    }
    out.retain(|(_, ty)| *ty != "?");
    out
}

/// Bit width of an integer type name (usize/isize treated as 64-bit
/// with the platform caveat handled by the caller).
fn int_bits(ty: &str) -> u32 {
    match ty {
        "u8" | "i8" => 8,
        "u16" | "i16" => 16,
        "u32" | "i32" => 32,
        "u64" | "i64" | "usize" | "isize" => 64,
        _ => 128,
    }
}

/// Why `src as dst` can lose information, or `None` when the cast is
/// value-preserving.  `src == "float-lit"` marks a float literal
/// source.  Int → `f64` is deliberately not flagged: every counter in
/// the tree stays far below 2⁵³ and the accounting CSVs are
/// f64-formatted by contract.
fn cast_loss(src: &str, dst: &str) -> Option<String> {
    let fsrc = src == "float-lit" || FLOAT_TYPES.contains(&src);
    let fdst = FLOAT_TYPES.contains(&dst);
    if fsrc && !fdst {
        return Some(format!("float → `{dst}` truncates toward zero and saturates"));
    }
    if fsrc && fdst {
        return (src == "f64" && dst == "f32")
            .then(|| "`f64` → `f32` silently rounds to 24-bit precision".to_string());
    }
    if !fsrc && fdst {
        return (dst == "f32").then(|| {
            format!("`{src}` → `f32` loses integer precision above 2^24")
        });
    }
    // int → int.
    let (sb, db) = (int_bits(src), int_bits(dst));
    let (su, du) = (src.starts_with('u'), dst.starts_with('u'));
    let lossy = if su == du {
        db < sb || (src == "u64" && dst == "usize") || (src == "i64" && dst == "isize")
    } else if su {
        db <= sb // unsigned → signed needs strictly more bits
    } else {
        true // signed → unsigned wraps negatives
    };
    lossy.then(|| format!("`{src}` → `{dst}` can wrap or truncate"))
}

/// Dimension of the operand *ending* at code index `i`: the final
/// segment of a field path, unless it is a call (whose unit the name
/// suffix cannot vouch for).
fn operand_dim_at(code: &[&Tok], i: usize) -> Option<&'static str> {
    if code[i].kind != Kind::Ident {
        return None;
    }
    if i + 1 < code.len() && (code[i + 1].is_punct("(") || code[i + 1].is_punct("!")) {
        return None;
    }
    config::unit_dim(&code[i].text)
}

/// Effective dimension of the expression immediately left of the
/// operator at `i`, recognizing a trailing sanctioned conversion
/// (`wall_s * 1e3` is milliseconds; any other constant factor
/// preserves the dimension).
fn left_dim(code: &[&Tok], i: usize) -> Option<&'static str> {
    if i == 0 {
        return None;
    }
    let p = i - 1;
    if code[p].kind == Kind::Float && p >= 2 {
        let op = code[p - 1].text.as_str();
        if (op == "*" || op == "/") && code[p - 2].kind == Kind::Ident {
            let d = operand_dim_at(code, p - 2)?;
            return if config::conversion_factor(&code[p].text) {
                config::convert(d, op.chars().next().unwrap_or('*')).or(Some(d))
            } else {
                Some(d)
            };
        }
        return None;
    }
    // `n_tokens / epoch_s > …`: a product/quotient of tracked operands
    // (or a deref) is a composite whose dimension one suffix cannot
    // vouch for — rate definitions are legitimate cross-dimension math.
    if code[p].kind == Kind::Ident
        && p >= 1
        && (code[p - 1].is_punct("*") || code[p - 1].is_punct("/"))
    {
        return None;
    }
    operand_dim_at(code, p)
}

/// Effective dimension of the expression starting right of the
/// operator at `i`: walk a `recv.field.leaf` path to its final
/// segment, then apply a trailing sanctioned conversion if present.
fn right_dim(code: &[&Tok], i: usize) -> Option<&'static str> {
    let n = code.len();
    let mut j = i + 1;
    while j < n && (code[j].is_punct("&") || code[j].is_punct("*")) {
        j += 1;
    }
    if j >= n || code[j].kind != Kind::Ident {
        return None;
    }
    while j + 2 < n && code[j + 1].is_punct(".") && code[j + 2].kind == Kind::Ident {
        j += 2;
    }
    let d = operand_dim_at(code, j)?;
    // An `as` cast keeps the operand's dimension (`tokens as f64`).
    if j + 2 < n && code[j + 1].is_ident("as") && code[j + 2].kind == Kind::Ident {
        j += 2;
    }
    if j + 2 < n && (code[j + 1].is_punct("*") || code[j + 1].is_punct("/")) {
        let op = if code[j + 1].is_punct("*") { '*' } else { '/' };
        if code[j + 2].kind == Kind::Float {
            return if config::conversion_factor(&code[j + 2].text) {
                config::convert(d, op).or(Some(d))
            } else {
                Some(d) // dimensionless constant scale preserves `d`
            };
        }
        // `n_tokens / epoch_s`: a composite of tracked operands has no
        // single suffix dimension — rate definitions are legitimate.
        return None;
    }
    Some(d)
}

/// Run all eight rules over one file.
///
/// * `module` — module path (`cluster::events`), see [`config::module_path`];
/// * `rel` — path relative to the source root, forward slashes
///   (drives the R3 fingerprint-file scope);
/// * `toks` — full token stream including comments.
pub fn analyze(module: &str, rel: &str, toks: &[Tok]) -> Vec<Violation> {
    let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();
    let mut out: Vec<Violation> = Vec::new();

    let critical = config::module_in(&config::CRITICAL_MODULES, module);
    let clock_ok = config::module_in(&config::WALL_CLOCK_ALLOW, module);
    let spawn_ok = config::module_in(&config::SPAWN_ALLOW, module);
    let rng_ok = config::module_in(&config::RNG_ALLOW, module);
    let fingerprint_file = config::FLOAT_KEY_FILES.iter().any(|f| rel.ends_with(f));
    let cast_scoped = config::module_in(&config::LOSSY_CAST_MODULES, module);
    let panic_scoped = config::module_in(&config::PANIC_PATH_MODULES, module);
    let test_ranges = test_line_ranges(&code);

    // ---- R1: unordered iteration over hash collections -------------
    if critical {
        let hashed = tracked_bindings(&code, &["HashMap", "HashSet"]);
        let is_hashed = |t: &Tok| t.kind == Kind::Ident && hashed.iter().any(|n| *n == t.text);
        for (i, t) in code.iter().enumerate() {
            // `map.iter()`, `self.map.keys()`, `map.drain()`, …
            if is_hashed(t)
                && i + 2 < code.len()
                && code[i + 1].is_punct(".")
                && code[i + 2].kind == Kind::Ident
                && ITER_METHODS.contains(&code[i + 2].text.as_str())
            {
                out.push(Violation {
                    line: t.line,
                    rule: "unordered-iter",
                    msg: format!(
                        "`{}.{}()` iterates a hash collection in determinism-critical \
                         module `{}`; use BTreeMap/BTreeSet or sort first",
                        t.text, code[i + 2].text, module
                    ),
                });
            }
            // `for x in &map { … }` / `for x in map { … }`
            if t.is_ident("in") {
                let mut k = i + 1;
                while k < code.len() && (code[k].is_punct("&") || code[k].is_ident("mut")) {
                    k += 1;
                }
                if k + 1 < code.len() && is_hashed(code[k]) && code[k + 1].is_punct("{") {
                    out.push(Violation {
                        line: code[k].line,
                        rule: "unordered-iter",
                        msg: format!(
                            "`for … in {}` iterates a hash collection in \
                             determinism-critical module `{}`",
                            code[k].text, module
                        ),
                    });
                }
            }
        }
    }

    // ---- R2: wall clocks outside timing modules --------------------
    if !clock_ok {
        for (i, t) in code.iter().enumerate() {
            if t.is_ident("Instant")
                && i + 2 < code.len()
                && code[i + 1].is_punct("::")
                && code[i + 2].is_ident("now")
            {
                out.push(Violation {
                    line: t.line,
                    rule: "wall-clock",
                    msg: format!(
                        "`Instant::now()` outside timing allowlist (module `{module}`); \
                         wall time must not influence simulated state"
                    ),
                });
            }
            if t.is_ident("SystemTime") {
                out.push(Violation {
                    line: t.line,
                    rule: "wall-clock",
                    msg: format!("`SystemTime` outside timing allowlist (module `{module}`)"),
                });
            }
        }
    }

    // ---- R3: raw floats in memo-key / fingerprint code -------------
    if fingerprint_file {
        let floats = tracked_bindings(&code, &["f64", "f32"]);
        let is_float =
            |t: &Tok| t.kind == Kind::Float || floats.iter().any(|n| t.is_ident(n.as_str()));
        for (i, t) in code.iter().enumerate() {
            if (t.is_punct("==") || t.is_punct("!="))
                && i > 0
                && i + 1 < code.len()
                && (is_float(code[i - 1]) || is_float(code[i + 1]))
            {
                out.push(Violation {
                    line: t.line,
                    rule: "float-key",
                    msg: "float comparison in fingerprint path; compare `to_bits()` instead"
                        .to_string(),
                });
            }
            if t.is_ident("as")
                && i > 0
                && i + 1 < code.len()
                && INT_TYPES.contains(&code[i + 1].text.as_str())
                && is_float(code[i - 1])
            {
                out.push(Violation {
                    line: t.line,
                    rule: "float-key",
                    msg: format!(
                        "float → `{}` cast in fingerprint path; use `to_bits()` for a \
                         total, lossless key",
                        code[i + 1].text
                    ),
                });
            }
        }
    }

    // ---- R4: ambient entropy (threads, unseeded randomness) --------
    for (i, t) in code.iter().enumerate() {
        if !spawn_ok
            && t.is_ident("thread")
            && i + 2 < code.len()
            && code[i + 1].is_punct("::")
            && code[i + 2].is_ident("spawn")
        {
            out.push(Violation {
                line: t.line,
                rule: "ambient-entropy",
                msg: format!(
                    "`thread::spawn` outside util::threadpool (module `{module}`); \
                     ad-hoc threads make completion order a scheduling artifact"
                ),
            });
        }
        if !rng_ok && t.kind == Kind::Ident && ENTROPY_IDENTS.contains(&t.text.as_str()) {
            out.push(Violation {
                line: t.line,
                rule: "ambient-entropy",
                msg: format!(
                    "`{}` outside util::rng (module `{module}`); all randomness must \
                     be seeded",
                    t.text
                ),
            });
        }
    }

    // ---- R5: deprecated APIs must not exist or be used -------------
    for (i, t) in code.iter().enumerate() {
        if t.is_ident("deprecated") {
            let suppressed =
                i >= 2 && code[i - 1].is_punct("(") && code[i - 2].is_ident("allow");
            out.push(Violation {
                line: t.line,
                rule: "deprecated",
                msg: if suppressed {
                    "`#[allow(deprecated)]` hides use of a deprecated API".to_string()
                } else {
                    "`deprecated` marker: in-crate deprecated APIs must be removed, \
                     not accumulated"
                        .to_string()
                },
            });
        }
    }

    // ---- R6: mixed unit suffixes in arithmetic / assignment --------
    // Applies everywhere (the suffix convention is tree-wide), outside
    // test code.  The canonical finding class is the report-boundary
    // seam: seconds-typed internals leaking raw into `*_ms` columns —
    // fixed once via `engine::metrics::ReportSchema::ms_from_s` (§13).
    for (i, t) in code.iter().enumerate() {
        if in_ranges(&test_ranges, t.line) {
            continue;
        }
        // Binary arithmetic / comparison between differently-dimensioned
        // operands.
        if t.kind == Kind::Punct
            && matches!(t.text.as_str(), "+" | "-" | "<" | ">" | "<=" | ">=" | "==" | "!=")
        {
            if let (Some(l), Some(r)) = (left_dim(&code, i), right_dim(&code, i)) {
                if l != r {
                    out.push(Violation {
                        line: t.line,
                        rule: "unit-mix",
                        msg: format!(
                            "`{}` mixes units: left operand is {l}, right operand is {r}; \
                             convert through the sanctioned lattice first",
                            t.text
                        ),
                    });
                }
            }
        }
        // Assignment / struct-literal field: suffixed sink fed by a
        // differently-dimensioned suffixed source.
        if let Some(ldim) = operand_dim_at(&code, i) {
            let assigns = i + 1 < code.len()
                && (code[i + 1].is_punct("=")
                    || code[i + 1].is_punct(":")
                    || code[i + 1].is_punct("+=")
                    || code[i + 1].is_punct("-="));
            if assigns {
                if let Some(rdim) = right_dim(&code, i + 1) {
                    if rdim != ldim {
                        out.push(Violation {
                            line: t.line,
                            rule: "unit-mix",
                            msg: format!(
                                "`{}` ({ldim}) is assigned a {rdim}-dimensioned value; \
                                 convert at the seam (ReportSchema::ms_from_s style), \
                                 don't re-label",
                                t.text
                            ),
                        });
                    }
                }
            }
        }
    }

    // ---- R7: lossy `as` casts in accounting modules ----------------
    if cast_scoped {
        let typed = typed_bindings(&code);
        let type_of = |t: &Tok| {
            typed.iter().find(|(n, _)| t.is_ident(n)).map(|(_, ty)| *ty)
        };
        for (i, t) in code.iter().enumerate() {
            if !t.is_ident("as") || i == 0 || i + 1 >= code.len() {
                continue;
            }
            if in_ranges(&test_ranges, t.line) {
                continue;
            }
            let dst = code[i + 1].text.as_str();
            if !INT_TYPES.contains(&dst) && !FLOAT_TYPES.contains(&dst) {
                continue;
            }
            let prev = code[i - 1];
            let src: Option<&str> = if prev.kind == Kind::Float {
                Some("float-lit")
            } else if prev.is_punct(")")
                && i >= 4
                && code[i - 2].is_punct("(")
                && code[i - 4].is_punct(".")
                && (code[i - 3].is_ident("len") || code[i - 3].is_ident("count"))
            {
                Some("usize") // `.len() as …` / `.count() as …`
            } else if prev.kind == Kind::Ident {
                type_of(prev)
            } else {
                None
            };
            if let Some(why) = src.and_then(|s| cast_loss(s, dst)) {
                out.push(Violation {
                    line: t.line,
                    rule: "lossy-cast",
                    msg: format!(
                        "{why} in accounting module `{module}`; use try_from/try_into \
                         (or widen the destination) so overflow is an error, not a \
                         silent wrap"
                    ),
                });
            }
        }
    }

    // ---- R8: panic paths in the serving core -----------------------
    if panic_scoped {
        for (i, t) in code.iter().enumerate() {
            if in_ranges(&test_ranges, t.line) {
                continue;
            }
            let callish = i > 0
                && code[i - 1].is_punct(".")
                && i + 1 < code.len()
                && code[i + 1].is_punct("(");
            if callish && (t.is_ident("unwrap") || t.is_ident("expect")) {
                out.push(Violation {
                    line: t.line,
                    rule: "panic-path",
                    msg: format!(
                        "`.{}(…)` in serving hot path `{module}` kills the whole horizon \
                         on failure; return a contextual error or use a total fallback",
                        t.text
                    ),
                });
            }
            if (t.is_ident("panic") || t.is_ident("unreachable"))
                && i + 1 < code.len()
                && code[i + 1].is_punct("!")
            {
                out.push(Violation {
                    line: t.line,
                    rule: "panic-path",
                    msg: format!(
                        "`{}!` in serving hot path `{module}`; make the impossible case \
                         a typed error so a bad input cannot abort a horizon",
                        t.text
                    ),
                });
            }
            // Direct indexing with a non-literal index.
            if t.is_punct("[") && i > 0 {
                let p = code[i - 1];
                let indexes = (p.kind == Kind::Ident
                    && !NON_INDEX_PREV.contains(&p.text.as_str()))
                    || p.is_punct(")")
                    || p.is_punct("]");
                if indexes {
                    // Matching bracket; literal-only and full-range
                    // (`[..]`) contents are infallible.
                    let mut depth = 0usize;
                    let mut j = i;
                    while j < code.len() {
                        if code[j].is_punct("[") {
                            depth += 1;
                        } else if code[j].is_punct("]") {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        j += 1;
                    }
                    let inner = &code[i + 1..j.min(code.len())];
                    let literal = inner.len() == 1 && inner[0].kind == Kind::Int;
                    let full_range = inner.len() == 1 && inner[0].is_punct("..");
                    if !inner.is_empty() && !literal && !full_range {
                        let recv = if p.kind == Kind::Ident { p.text.as_str() } else { "…" };
                        out.push(Violation {
                            line: t.line,
                            rule: "panic-path",
                            msg: format!(
                                "non-literal index `{recv}[…]` in serving hot path \
                                 `{module}` can panic out of bounds; use .get()/iterators \
                                 or prove the bound and waive"
                            ),
                        });
                    }
                }
            }
        }
    }

    out.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    out.dedup_by(|a, b| a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    out
}

/// Extract waiver annotations from a file's comment tokens.
pub fn parse_waivers(toks: &[Tok]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks {
        if t.kind != Kind::Comment {
            continue;
        }
        let Some(at) = t.text.find("detlint:") else { continue };
        let rest = &t.text[at + "detlint:".len()..];
        let Some(open) = rest.find("allow(") else { continue };
        let after = &rest[open + "allow(".len()..];
        let Some(close) = after.find(')') else { continue };
        let rule = after[..close].trim().to_string();
        let reason = after[close + 1..]
            .trim_start_matches([' ', '\t', '—', '–', '-', ':'])
            .trim_end_matches(['*', '/', ' '])
            .trim()
            .to_string();
        out.push(Waiver { line: t.line, rule, reason });
    }
    out
}

/// Does `w` cover a violation of `rule` at `line`?  A waiver applies
/// on its own line or up to two lines above (so `#[allow(...)]`
/// attribute lines can sit between the comment and the code).
pub fn waiver_covers(w: &Waiver, rule: &str, line: u32) -> bool {
    w.rule == rule && w.line <= line && line <= w.line + 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn run(module: &str, rel: &str, src: &str) -> Vec<Violation> {
        analyze(module, rel, &lex(src))
    }

    #[test]
    fn seeded_hashmap_iteration_in_cluster_events_is_flagged() {
        // The acceptance-criteria scenario: a synthetic violation in
        // cluster/events.rs must produce a file:line diagnostic.
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n\
                   let mut route: HashMap<usize, usize> = HashMap::new();\n\
                   route.insert(1, 2);\n\
                   for (k, v) in route.iter() { println!(\"{k}{v}\"); }\n\
                   }\n";
        let v = run("cluster::events", "cluster/events.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unordered-iter");
        assert_eq!(v[0].line, 5);
    }

    #[test]
    fn for_in_ref_over_hashset_is_flagged() {
        let src = "use std::collections::HashSet;\n\
                   fn f(placed: &HashSet<usize>) {\n\
                   for p in placed { let _ = p; }\n\
                   }\n";
        let v = run("placement::replan", "placement/replan.rs", src);
        assert!(v.iter().any(|x| x.rule == "unordered-iter" && x.line == 3), "{v:?}");
    }

    #[test]
    fn lookup_only_hashmap_is_clean_and_noncritical_modules_ignored() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<usize, usize>) -> Option<&usize> { m.get(&1) }\n";
        assert!(run("cluster::events", "cluster/events.rs", src).is_empty());
        let iterating = "use std::collections::HashMap;\n\
                         fn f(m: &HashMap<usize, usize>) { for x in m.iter() { let _ = x; } }\n";
        assert!(run("experiments::fleet", "experiments/fleet.rs", iterating).is_empty());
    }

    #[test]
    fn btreemap_iteration_is_clean() {
        let src = "use std::collections::BTreeMap;\n\
                   fn f(m: &BTreeMap<usize, usize>) { for x in m.iter() { let _ = x; } }\n";
        assert!(run("cluster::events", "cluster/events.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_scoping() {
        let src = "use std::time::Instant;\nfn f() { let _t = Instant::now(); }\n";
        let v = run("engine::kv", "engine/kv.rs", src);
        assert!(v.iter().any(|x| x.rule == "wall-clock" && x.line == 2), "{v:?}");
        assert!(run("util::bench", "util/bench.rs", src).is_empty());
        assert!(run("experiments::fleet", "experiments/fleet.rs", src).is_empty());
        assert!(run("engine", "engine/mod.rs", src).is_empty());
    }

    #[test]
    fn float_key_rules_fire_only_in_fingerprint_files() {
        let src = "fn key(v: f64) -> u64 { if v == 0.0 { 0 } else { v as u64 } }\n";
        let v = run("placement::estimator", "placement/estimator.rs", src);
        assert_eq!(v.iter().filter(|x| x.rule == "float-key").count(), 2, "{v:?}");
        assert!(run("ml::features", "ml/features.rs", src).is_empty());
    }

    #[test]
    fn ambient_entropy_and_deprecated() {
        let spawn = "fn f() { std::thread::spawn(|| {}); }\n";
        assert!(!run("cluster::mod_", "cluster/x.rs", spawn).is_empty());
        assert!(run("util::threadpool", "util/threadpool.rs", spawn).is_empty());
        let dep = "#[deprecated(note = \"gone\")]\nfn old() {}\n";
        assert!(run("config", "config.rs", dep).iter().any(|x| x.rule == "deprecated"));
        let sup = "#[allow(deprecated)]\nfn f() {}\n";
        let v = run("config", "config.rs", sup);
        assert!(v.iter().any(|x| x.rule == "deprecated" && x.msg.contains("hides")));
    }

    #[test]
    fn waivers_parse_and_cover_nearby_lines() {
        let src = "// detlint: allow(unordered-iter) — snapshot is sorted immediately after\n\
                   #[allow(clippy::disallowed_types)]\n\
                   fn f() {}\n";
        let ws = parse_waivers(&lex(src));
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].rule, "unordered-iter");
        assert_eq!(ws[0].reason, "snapshot is sorted immediately after");
        assert!(waiver_covers(&ws[0], "unordered-iter", 1));
        assert!(waiver_covers(&ws[0], "unordered-iter", 3));
        assert!(!waiver_covers(&ws[0], "unordered-iter", 4));
        assert!(!waiver_covers(&ws[0], "wall-clock", 1));
    }

    #[test]
    fn waiver_reason_may_be_empty_for_driver_to_reject() {
        let ws = parse_waivers(&lex("// detlint: allow(wall-clock)\n"));
        assert_eq!(ws.len(), 1);
        assert!(ws[0].reason.is_empty());
    }

    // ---- R6 unit-mix ------------------------------------------------

    #[test]
    fn unit_mix_flags_cross_dimension_arithmetic_and_comparison() {
        let v = run(
            "engine::metrics",
            "engine/metrics.rs",
            "fn f(a_s: f64, b_ms: f64) -> f64 { a_s + b_ms }\n",
        );
        assert!(v.iter().any(|x| x.rule == "unit-mix" && x.line == 1), "{v:?}");
        let v = run(
            "cluster::epochs",
            "cluster/epochs.rs",
            "fn f(ttft_s: f64, deadline_ms: f64) -> bool {\nttft_s > deadline_ms\n}\n",
        );
        assert!(v.iter().any(|x| x.rule == "unit-mix" && x.line == 2), "{v:?}");
        let v = run(
            "dt::twin",
            "dt/twin.rs",
            "fn f(n_tokens: f64, kv_bytes: f64) -> bool { n_tokens == kv_bytes }\n",
        );
        assert!(v.iter().any(|x| x.rule == "unit-mix"), "{v:?}");
    }

    #[test]
    fn unit_mix_flags_cross_dimension_assignment_and_field_init() {
        let v = run(
            "engine::metrics",
            "engine/metrics.rs",
            "fn f(w_s: f64) { let mut t_ms = 0.0; t_ms = w_s; }\n",
        );
        assert!(v.iter().any(|x| x.rule == "unit-mix" && x.msg.contains("t_ms")), "{v:?}");
        let v = run(
            "engine::metrics",
            "engine/metrics.rs",
            "fn f(r: &Rep) -> Row { Row { ttft_ms: r.ttft_mean_s } }\n",
        );
        assert!(v.iter().any(|x| x.rule == "unit-mix" && x.msg.contains("ttft_ms")), "{v:?}");
    }

    #[test]
    fn unit_mix_accepts_sanctioned_conversions_and_same_dimension() {
        // The sanctioned lattice: `*_s * 1000.0 → *_ms` both in
        // arithmetic and at assignment seams.
        let clean = [
            "fn f(a_s: f64, b_s: f64) -> f64 { a_s + b_s }\n",
            "fn f(w_s: f64, t_ms: f64) -> f64 { w_s * 1e3 + t_ms }\n",
            "fn f(w_s: f64, t_ms: f64) -> f64 { t_ms + w_s * 1000.0 }\n",
            "fn f(r: &Rep) -> Row { Row { ttft_ms: r.ttft_mean_s * 1e3 } }\n",
            "fn f(t_ms: f64) { let wall_s = t_ms / 1e3; let _ = wall_s; }\n",
            // Scaling by a dimensionless factor preserves the dimension.
            "fn f(a_s: f64, b_s: f64) -> f64 { a_s * 0.9 + b_s }\n",
            // Rates × times are legitimate cross-dimension products.
            "fn f(r_tok_s: f64, dt_s: f64) -> f64 { r_tok_s * dt_s }\n",
            // Rate definitions: a quotient of tracked operands is a
            // composite with no single suffix dimension (the canonical
            // tree shape is `incoming_tok_s: arrived_tokens / epoch_s`).
            "fn f(n_tokens: u64, dt_s: f64) -> Row { Row { r_tok_s: n_tokens as f64 / dt_s } }\n",
            "fn f(n_tokens: f64, dt_s: f64, r_tok_s: f64) -> bool { n_tokens / dt_s > r_tok_s }\n",
        ];
        for src in clean {
            let v = run("engine::metrics", "engine/metrics.rs", src);
            assert!(v.iter().all(|x| x.rule != "unit-mix"), "false positive on {src:?}: {v:?}");
        }
    }

    #[test]
    fn unit_mix_ignores_calls_tests_and_unsuffixed_operands() {
        // A call's unit cannot be vouched for by its name suffix.
        let v = run(
            "engine::metrics",
            "engine/metrics.rs",
            "fn f(t_ms: f64) -> f64 { t_ms + elapsed_s() }\n",
        );
        assert!(v.is_empty(), "{v:?}");
        // Unsuffixed operands have no dimension.
        let v = run(
            "engine::metrics",
            "engine/metrics.rs",
            "fn f(t_ms: f64, n: f64) -> f64 { t_ms + n }\n",
        );
        assert!(v.is_empty(), "{v:?}");
        // Test code is exempt from the correctness rules.
        let src = "#[cfg(test)]\nmod tests {\nfn f(a_s: f64, b_ms: f64) -> f64 { a_s + b_ms }\n}\n";
        let v = run("engine::metrics", "engine/metrics.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }

    /// Satellite no-false-positive fixtures: suffixed identifiers
    /// inside raw/byte strings and nested block comments must not trip
    /// `unit-mix` (the lexer drops string contents and the rule pass
    /// drops comments).
    #[test]
    fn unit_mix_ignores_strings_and_comments() {
        let raw = "fn f() -> &'static str { r#\"ttft_s + itl_ms\"# }\n";
        assert!(run("engine::metrics", "engine/metrics.rs", raw).is_empty());
        let byte = "fn f() -> &'static [u8] { b\"wall_s < wall_ms\" }\n";
        assert!(run("engine::metrics", "engine/metrics.rs", byte).is_empty());
        let comment = "/* a_s + b_ms /* nested: ttft_s > itl_ms */ still comment */\nfn f() {}\n";
        assert!(run("engine::metrics", "engine/metrics.rs", comment).is_empty());
    }

    // ---- R7 lossy-cast ----------------------------------------------

    #[test]
    fn lossy_cast_flags_truncating_and_wrapping_casts() {
        let cases = [
            ("fn f(x: f64) -> u64 { x as u64 }\n", "float → int"),
            ("fn f(n: u64) -> u32 { n as u32 }\n", "u64 → u32"),
            ("fn f(n: u64) -> usize { n as usize }\n", "u64 → usize"),
            ("fn f(n: i64) -> u64 { n as u64 }\n", "signed → unsigned"),
            ("fn f(n: u64) -> f32 { n as f32 }\n", "int → f32"),
            ("fn f(x: f64) -> f32 { x as f32 }\n", "f64 → f32"),
            ("fn f() -> u64 { 1.5 as u64 }\n", "float literal → int"),
            ("fn f(v: &[u8]) -> u32 { v.len() as u32 }\n", "len() → u32"),
        ];
        for (src, what) in cases {
            let v = run("cluster::events", "cluster/events.rs", src);
            assert!(v.iter().any(|x| x.rule == "lossy-cast"), "missed {what}: {v:?}");
        }
    }

    #[test]
    fn lossy_cast_accepts_value_preserving_casts_and_out_of_scope() {
        let clean = [
            "fn f(n: usize) -> u64 { n as u64 }\n",
            "fn f(n: u32) -> usize { n as usize }\n",
            "fn f(n: u32) -> i64 { n as i64 }\n",
            // int → f64 is the accounting contract (counters ≪ 2^53).
            "fn f(n: usize) -> f64 { n as f64 }\n",
            "fn f(v: &[u8]) -> f64 { v.len() as f64 }\n",
            "fn f(v: &[u8]) -> u64 { v.len() as u64 }\n",
        ];
        for src in clean {
            let v = run("cluster::events", "cluster/events.rs", src);
            assert!(v.iter().all(|x| x.rule != "lossy-cast"), "false positive on {src:?}: {v:?}");
        }
        // Out of the accounting scope the rule stays quiet.
        let lossy = "fn f(x: f64) -> u64 { x as u64 }\n";
        assert!(run("ml::features", "ml/features.rs", lossy).is_empty());
        assert!(run("workload::arrivals", "workload/arrivals.rs", lossy).is_empty());
    }

    /// Satellite no-false-positive fixture: `as` inside a string
    /// literal must not trip `lossy-cast`.
    #[test]
    fn lossy_cast_ignores_as_inside_strings_and_tests() {
        let s = "fn f(x: f64) -> String { format!(\"cast x as u64 = {}\", x) }\n";
        assert!(run("cluster::events", "cluster/events.rs", s).is_empty());
        let raw = "fn f() -> &'static str { r\"1.5 as u32\" }\n";
        assert!(run("cluster::events", "cluster/events.rs", raw).is_empty());
        let test = "#[cfg(test)]\nmod tests {\nfn f(x: f64) -> u64 { x as u64 }\n}\n";
        assert!(run("cluster::events", "cluster/events.rs", test).is_empty());
    }

    // ---- R8 panic-path ----------------------------------------------

    #[test]
    fn panic_path_flags_unwrap_expect_panic_and_nonliteral_indexing() {
        let v = run(
            "cluster::events",
            "cluster/events.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        assert!(v.iter().any(|x| x.rule == "panic-path" && x.msg.contains("unwrap")), "{v:?}");
        let v = run(
            "engine::scheduler",
            "engine/scheduler.rs",
            "fn f(x: Option<u32>) -> u32 { x.expect(\"missing\") }\n",
        );
        assert!(v.iter().any(|x| x.rule == "panic-path" && x.msg.contains("expect")), "{v:?}");
        let v = run("dt::twin", "dt/twin.rs", "fn f(bad: bool) { if bad { panic!(\"boom\") } }\n");
        assert!(v.iter().any(|x| x.rule == "panic-path" && x.msg.contains("panic")), "{v:?}");
        let v = run("placement::greedy", "placement/greedy.rs", "fn f() { unreachable!() }\n");
        assert!(v.iter().any(|x| x.rule == "panic-path"), "{v:?}");
        let v = run(
            "placement::replan",
            "placement/replan.rs",
            "fn f(xs: &[f64], i: usize) -> f64 { xs[i] }\n",
        );
        assert!(v.iter().any(|x| x.rule == "panic-path" && x.msg.contains("index")), "{v:?}");
        let v = run(
            "cluster::events",
            "cluster/events.rs",
            "fn f(xs: &[f64], n: usize) -> &[f64] { &xs[..n] }\n",
        );
        assert!(v.iter().any(|x| x.rule == "panic-path"), "range slicing can panic: {v:?}");
    }

    #[test]
    fn panic_path_accepts_total_alternatives_and_out_of_scope() {
        let clean = [
            "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n",
            "fn f(x: Option<u32>) -> u32 { x.unwrap_or_default() }\n",
            "fn f(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 1) }\n",
            "fn f(xs: &[f64], i: usize) -> Option<&f64> { xs.get(i) }\n",
            "fn f(xs: &[f64; 4]) -> f64 { xs[0] }\n",
            "fn f(xs: &[f64]) -> &[f64] { &xs[..] }\n",
            "fn f(xs: &mut [f64]) { for x in xs.iter_mut() { *x += 1.0; } }\n",
            "fn f() -> [u8; 2] { [1, 2] }\n",
        ];
        for src in clean {
            let v = run("cluster::events", "cluster/events.rs", src);
            assert!(v.iter().all(|x| x.rule != "panic-path"), "false positive on {src:?}: {v:?}");
        }
        // Outside the hot-path scope (and in test code) panics are fine.
        let unwrap = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(run("util::csv", "util/csv.rs", unwrap).is_empty());
        assert!(run("experiments::drift", "experiments/drift.rs", unwrap).is_empty());
        let test = "#[cfg(test)]\nmod tests {\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        assert!(run("cluster::events", "cluster/events.rs", test).is_empty());
    }

    #[test]
    fn test_line_ranges_cover_gated_items_only() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn a() {}\n\
                   }\n\
                   fn also_live() {}\n";
        let toks = lex(src);
        let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();
        let r = test_line_ranges(&code);
        assert_eq!(r, vec![(2, 5)]);
        // `#[cfg(test)] use …;` ends at the semicolon.
        let toks = lex("#[cfg(test)]\nuse std::collections::HashMap;\nfn f() {}\n");
        let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();
        assert_eq!(test_line_ranges(&code), vec![(1, 2)]);
    }
}
