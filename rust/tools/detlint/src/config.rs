//! The ruleset configuration: which modules each rule applies to, how
//! module paths are matched, and the unit-suffix dimension table.
//!
//! Allowlist / scope entries come in two forms:
//!
//! * `"util::bench"` — exact module match only;
//! * `"experiments::*"` — the module itself (`experiments`) and its
//!   whole subtree (`experiments::fleet`, …).
//!
//! Module paths are derived from the file path relative to `rust/src`:
//! `cluster/events.rs → cluster::events`, `cluster/mod.rs → cluster`,
//! `main.rs → main`, `lib.rs → lib`.  Files under the self-lint roots
//! get a namespace prefix: `rust/tools/detlint/src/rules.rs →
//! tools::detlint::rules`, `rust/benches/plan.rs → benches::plan`.

/// All eight rule identifiers, in report order.
pub const RULE_IDS: [&str; 8] = [
    "unordered-iter",
    "wall-clock",
    "float-key",
    "ambient-entropy",
    "deprecated",
    "unit-mix",
    "lossy-cast",
    "panic-path",
];

/// R1 — modules where unordered `HashMap`/`HashSet` iteration breaks
/// replay determinism (planner, twin, event core, workload gen, ML).
pub const CRITICAL_MODULES: [&str; 6] =
    ["cluster::*", "dt::*", "placement::*", "workload::*", "ml::*", "engine::*"];

/// R2 — modules allowed to read wall clocks. `engine` is exact: the
/// engine top module's contract *is* measured kernel time, but its
/// submodules (cache, kv, metrics) are pure bookkeeping.  The bench
/// harnesses (self-lint root `rust/benches`) are timing code by
/// definition.
pub const WALL_CLOCK_ALLOW: [&str; 5] =
    ["util::bench", "experiments::*", "main", "engine", "benches::*"];

/// R3 — file suffixes (relative to `rust/src`) that hold memo-key /
/// fingerprint code, where floats must round-trip via `to_bits()`.
pub const FLOAT_KEY_FILES: [&str; 3] =
    ["placement/estimator.rs", "placement/replan.rs", "pipeline/store.rs"];

/// R4 — the only module allowed to call `std::thread::spawn`.
pub const SPAWN_ALLOW: [&str; 1] = ["util::threadpool"];

/// R4 — the only module allowed to construct entropy (seed material);
/// everything else must take a seed.
pub const RNG_ALLOW: [&str; 1] = ["util::rng"];

/// R7 — accounting / counter modules where a truncating or wrapping
/// `as` cast silently corrupts the token, byte and latency totals that
/// the planner optimizes.
pub const LOSSY_CAST_MODULES: [&str; 5] =
    ["engine::metrics", "cluster::events", "dt::*", "placement::estimator", "pipeline::store"];

/// R8 — serving hot paths where a panic kills a whole horizon: the
/// event core, the engine iteration, the twin, and every planner pass.
pub const PANIC_PATH_MODULES: [&str; 4] = ["cluster::*", "engine::*", "dt::*", "placement::*"];

/// R6 — the unit-suffix table: identifier suffix → dimension.  Checked
/// in array order, so longer suffixes shadow their tails (`_tok_s`
/// before `_s`).  The dimension strings are opaque labels; two tracked
/// operands mix units iff their labels differ.
pub const UNIT_SUFFIXES: [(&str, &str); 7] = [
    ("_tok_s", "tok/s"),
    ("_req_s", "req/s"),
    ("_usd_hr", "usd/hr"),
    ("_ms", "ms"),
    ("_bytes", "bytes"),
    ("_tokens", "tokens"),
    ("_s", "s"),
];

/// Dimension of a unit-suffixed identifier, if any.  The suffix must
/// be proper (`wall_s` carries one, a bare `s` does not).
pub fn unit_dim(ident: &str) -> Option<&'static str> {
    UNIT_SUFFIXES
        .iter()
        .find(|(sfx, _)| ident.len() > sfx.len() && ident.ends_with(sfx))
        .map(|&(_, dim)| dim)
}

/// The sanctioned conversions of the dimension lattice:
/// `(from, op, to)` — multiplying or dividing a `from`-dimension
/// operand by a [`conversion_factor`] literal yields a `to`-dimension
/// value (`wall_s * 1e3` is milliseconds, `load_ms / 1e3` seconds).
pub const UNIT_CONVERSIONS: [(&str, char, &str); 2] = [("s", '*', "ms"), ("ms", '/', "s")];

/// Is this float literal one of the sanctioned scale factors (10³ in
/// any of the spellings the tree uses)?
pub fn conversion_factor(lit: &str) -> bool {
    matches!(lit.replace('_', "").as_str(), "1e3" | "1000.0" | "1000.")
}

/// Apply a sanctioned conversion: dimension of `dim <op> factor`.
/// `None` means the factor does not convert `dim` — scaling by a
/// dimensionless constant, which *preserves* the dimension.
pub fn convert(dim: &str, op: char) -> Option<&'static str> {
    UNIT_CONVERSIONS.iter().find(|&&(f, o, _)| f == dim && o == op).map(|&(_, _, t)| t)
}

/// Does `entry` (exact or `::*` subtree pattern) match `module`?
pub fn entry_matches(entry: &str, module: &str) -> bool {
    if let Some(prefix) = entry.strip_suffix("::*") {
        module == prefix || module.strip_prefix(prefix).is_some_and(|r| r.starts_with("::"))
    } else {
        module == entry
    }
}

/// Does any entry in `list` match `module`?
pub fn module_in(list: &[&str], module: &str) -> bool {
    list.iter().any(|e| entry_matches(e, module))
}

/// Derive the module path for a `.rs` file from its path relative to
/// the scanned source root (forward slashes).
pub fn module_path(rel: &str) -> String {
    let no_ext = rel.strip_suffix(".rs").unwrap_or(rel);
    let parts: Vec<&str> = no_ext.split('/').filter(|s| !s.is_empty() && *s != "src").collect();
    match parts.as_slice() {
        [] => String::new(),
        [.., "mod"] => parts[..parts.len() - 1].join("::"),
        _ => parts.join("::"),
    }
}

/// Module path for a file under a prefixed self-lint root
/// (`tools` / `benches`): `detlint/src/rules.rs` under `tools` →
/// `tools::detlint::rules` (the crate-layout `src` segment is
/// transparent, handled by [`module_path`]).
pub fn module_path_prefixed(prefix: &str, rel: &str) -> String {
    let inner = module_path(rel);
    if prefix.is_empty() {
        inner
    } else if inner.is_empty() {
        prefix.to_string()
    } else {
        format!("{prefix}::{inner}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths() {
        assert_eq!(module_path("cluster/events.rs"), "cluster::events");
        assert_eq!(module_path("cluster/mod.rs"), "cluster");
        assert_eq!(module_path("main.rs"), "main");
        assert_eq!(module_path("lib.rs"), "lib");
        assert_eq!(module_path("util/bench.rs"), "util::bench");
    }

    #[test]
    fn exact_vs_subtree_matching() {
        // Exact entry: module only, not submodules.
        assert!(entry_matches("engine", "engine"));
        assert!(!entry_matches("engine", "engine::kv"));
        // Subtree entry: root and all descendants, no sibling bleed.
        assert!(entry_matches("experiments::*", "experiments"));
        assert!(entry_matches("experiments::*", "experiments::fleet"));
        assert!(!entry_matches("experiments::*", "experiments_extra"));
    }

    #[test]
    fn prefixed_module_paths_for_self_lint_roots() {
        assert_eq!(module_path_prefixed("tools", "detlint/src/rules.rs"), "tools::detlint::rules");
        assert_eq!(module_path_prefixed("tools", "detlint/src/main.rs"), "tools::detlint::main");
        assert_eq!(module_path_prefixed("benches", "plan.rs"), "benches::plan");
        assert_eq!(module_path_prefixed("", "cluster/events.rs"), "cluster::events");
    }

    #[test]
    fn unit_dimension_table() {
        assert_eq!(unit_dim("wall_s"), Some("s"));
        assert_eq!(unit_dim("throughput_tok_s"), Some("tok/s"));
        assert_eq!(unit_dim("goodput_req_s"), Some("req/s"));
        assert_eq!(unit_dim("migration_cost_ms"), Some("ms"));
        assert_eq!(unit_dim("kv_handoff_bytes"), Some("bytes"));
        assert_eq!(unit_dim("backlog_tokens"), Some("tokens"));
        assert_eq!(unit_dim("cost_usd_hr"), Some("usd/hr"));
        // Proper suffix only, and no suffix means no dimension.
        assert_eq!(unit_dim("_s"), None);
        assert_eq!(unit_dim("stats"), None);
        assert_eq!(unit_dim("completed"), None);
    }

    #[test]
    fn sanctioned_conversions() {
        assert!(conversion_factor("1e3"));
        assert!(conversion_factor("1000.0"));
        assert!(conversion_factor("1_000.0"));
        assert!(!conversion_factor("0.9"));
        assert_eq!(convert("s", '*'), Some("ms"));
        assert_eq!(convert("ms", '/'), Some("s"));
        assert_eq!(convert("ms", '*'), None, "ms * 1e3 converts to nothing in the lattice");
        assert_eq!(convert("tokens", '*'), None);
    }

    #[test]
    fn critical_scope_covers_the_determinism_core() {
        for m in ["cluster::events", "dt::twin", "placement", "engine::adapter_cache"] {
            assert!(module_in(&CRITICAL_MODULES, m), "{m} must be critical");
        }
        for m in ["util::bench", "experiments::fleet", "runtime::pool", "config"] {
            assert!(!module_in(&CRITICAL_MODULES, m), "{m} must not be critical");
        }
    }
}
