//! The determinism ruleset configuration: which modules each rule
//! applies to, and how module paths are matched.
//!
//! Allowlist / scope entries come in two forms:
//!
//! * `"util::bench"` — exact module match only;
//! * `"experiments::*"` — the module itself (`experiments`) and its
//!   whole subtree (`experiments::fleet`, …).
//!
//! Module paths are derived from the file path relative to `rust/src`:
//! `cluster/events.rs → cluster::events`, `cluster/mod.rs → cluster`,
//! `main.rs → main`, `lib.rs → lib`.

/// All five rule identifiers, in report order.
pub const RULE_IDS: [&str; 5] =
    ["unordered-iter", "wall-clock", "float-key", "ambient-entropy", "deprecated"];

/// R1 — modules where unordered `HashMap`/`HashSet` iteration breaks
/// replay determinism (planner, twin, event core, workload gen, ML).
pub const CRITICAL_MODULES: [&str; 6] =
    ["cluster::*", "dt::*", "placement::*", "workload::*", "ml::*", "engine::*"];

/// R2 — modules allowed to read wall clocks. `engine` is exact: the
/// engine top module's contract *is* measured kernel time, but its
/// submodules (cache, kv, metrics) are pure bookkeeping.
pub const WALL_CLOCK_ALLOW: [&str; 4] = ["util::bench", "experiments::*", "main", "engine"];

/// R3 — file suffixes (relative to `rust/src`) that hold memo-key /
/// fingerprint code, where floats must round-trip via `to_bits()`.
pub const FLOAT_KEY_FILES: [&str; 3] =
    ["placement/estimator.rs", "placement/replan.rs", "pipeline/store.rs"];

/// R4 — the only module allowed to call `std::thread::spawn`.
pub const SPAWN_ALLOW: [&str; 1] = ["util::threadpool"];

/// R4 — the only module allowed to construct entropy (seed material);
/// everything else must take a seed.
pub const RNG_ALLOW: [&str; 1] = ["util::rng"];

/// Does `entry` (exact or `::*` subtree pattern) match `module`?
pub fn entry_matches(entry: &str, module: &str) -> bool {
    if let Some(prefix) = entry.strip_suffix("::*") {
        module == prefix || module.strip_prefix(prefix).is_some_and(|r| r.starts_with("::"))
    } else {
        module == entry
    }
}

/// Does any entry in `list` match `module`?
pub fn module_in(list: &[&str], module: &str) -> bool {
    list.iter().any(|e| entry_matches(e, module))
}

/// Derive the module path for a `.rs` file from its path relative to
/// the scanned source root (forward slashes).
pub fn module_path(rel: &str) -> String {
    let no_ext = rel.strip_suffix(".rs").unwrap_or(rel);
    let parts: Vec<&str> = no_ext.split('/').filter(|s| !s.is_empty()).collect();
    match parts.as_slice() {
        [] => String::new(),
        [.., "mod"] => parts[..parts.len() - 1].join("::"),
        _ => parts.join("::"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths() {
        assert_eq!(module_path("cluster/events.rs"), "cluster::events");
        assert_eq!(module_path("cluster/mod.rs"), "cluster");
        assert_eq!(module_path("main.rs"), "main");
        assert_eq!(module_path("lib.rs"), "lib");
        assert_eq!(module_path("util/bench.rs"), "util::bench");
    }

    #[test]
    fn exact_vs_subtree_matching() {
        // Exact entry: module only, not submodules.
        assert!(entry_matches("engine", "engine"));
        assert!(!entry_matches("engine", "engine::kv"));
        // Subtree entry: root and all descendants, no sibling bleed.
        assert!(entry_matches("experiments::*", "experiments"));
        assert!(entry_matches("experiments::*", "experiments::fleet"));
        assert!(!entry_matches("experiments::*", "experiments_extra"));
    }

    #[test]
    fn critical_scope_covers_the_determinism_core() {
        for m in ["cluster::events", "dt::twin", "placement", "engine::adapter_cache"] {
            assert!(module_in(&CRITICAL_MODULES, m), "{m} must be critical");
        }
        for m in ["util::bench", "experiments::fleet", "runtime::pool", "config"] {
            assert!(!module_in(&CRITICAL_MODULES, m), "{m} must not be critical");
        }
    }
}
