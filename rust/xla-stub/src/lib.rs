//! API-compatible stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links against a native `xla_extension` build that is not
//! vendored in this repository.  This stub mirrors exactly the surface the
//! `adapter_serving::runtime::pjrt` backend consumes so the PJRT code path
//! stays type-checked (`cargo check --features pjrt`) on every change;
//! every runtime entry point returns [`Error::Unavailable`].  Deploying the
//! real backend means pointing the `xla` path dependency at a vendored
//! xla-rs checkout instead — no source changes on the adapter_serving side.

use std::path::Path;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub enum Error {
    /// The stub was invoked at runtime.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real xla-rs crate (vendor it \
                 over rust/xla-stub; see DESIGN.md §2.3)"
            ),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (stub: never instantiated).
#[derive(Debug)]
pub struct Literal {
    _opaque: (),
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::Unavailable("Literal::to_tuple3"))
    }
}

/// Deserialization from raw byte containers (npy/npz readers in xla-rs).
pub trait FromRawBytes: Sized {
    type Context;

    fn read_npz_by_name<P: AsRef<Path>>(
        path: P,
        context: &Self::Context,
        names: &[&str],
    ) -> Result<Vec<Self>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz_by_name<P: AsRef<Path>>(
        _path: P,
        _context: &Self::Context,
        _names: &[&str],
    ) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::read_npz_by_name"))
    }
}

/// One PJRT device (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtDevice {
    _opaque: (),
}

/// Device-resident buffer (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtBuffer {
    _opaque: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _opaque: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _opaque: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_literal"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _opaque: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _opaque: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _opaque: () }
    }
}
